//! Dense kernels for the native backend — forward and backward.
//!
//! All tensors are row-major `f32` slices. Every kernel obeys the
//! determinism rule from [`super::par`]: threads partition **output**
//! rows/elements only, and each output element is a sequential reduction
//! in a fixed order (ascending reduction index), so results are
//! bit-identical for every thread count. Reductions that cross the row
//! axis (weight/bias gradients, losses) partition the *gradient* rows or
//! run single-threaded — never split the summation itself.
//!
//! The hot matmul-family kernels are register-tiled: output columns are
//! processed in tiles of [`LANES`] with the tile's partial sums held in a
//! stack accumulator array, so the compiler keeps them in one SIMD
//! register across the whole reduction loop instead of re-loading the
//! output row every iteration. Vectorization runs **across output
//! elements** (different accumulators), never across a single element's
//! reduction, so the per-element addition order is exactly the scalar
//! kernel's and results stay bit-identical to the untiled form. The final
//! `width % LANES` outputs run the same ascending reduction at partial
//! width — the scalar-tail contract (see `docs/PERFORMANCE.md`).
//!
//! Kernels take explicit dims and several buffers; the argument counts
//! and index-heavy reduction loops are the point, so the corresponding
//! clippy style lints are allowed file-wide.
#![allow(clippy::too_many_arguments, clippy::needless_range_loop)]

use crate::{Error, Result};

use super::par::{join_all, par_rows};

/// Register-tile width for the matmul-family kernels: 8 × f32 = one
/// 256-bit vector. A tile's accumulators live in a `[f32; LANES]` array
/// that never aliases the input slices, which is what lets rustc keep it
/// in a register and vectorize the `LANES` independent FMA chains.
pub const LANES: usize = 8;

/// Row-blocking factor for [`grad_w`]: each block of `ROW_BLOCK` batch
/// rows is swept once per column tile, so the strided `x` column reads
/// and the `dz` rows stay L1-resident across tiles. Blocking only splits
/// the ascending-`r` walk into consecutive runs — the per-element
/// addition order is unchanged.
const ROW_BLOCK: usize = 64;

/// `orow = init (bias or zeros) + xrow @ w` for one output row — the
/// shared register-tiled core of [`linear_fwd`] / [`matmul_fwd`] and the
/// fused [`codebook_linear_fwd`]. `w` is `(d_in, d_out)` row-major with
/// `d_in == xrow.len()`. Each column tile accumulates over `k` in
/// ascending order from its init value, exactly like the scalar loop; the
/// tail columns (`d_out % LANES`) do the same at partial width.
#[inline]
fn row_matmul_tiled(xrow: &[f32], w: &[f32], d_out: usize, init: Option<&[f32]>, orow: &mut [f32]) {
    debug_assert_eq!(w.len(), xrow.len() * d_out);
    debug_assert_eq!(orow.len(), d_out);
    let mut o0 = 0;
    while o0 + LANES <= d_out {
        let mut acc = [0.0f32; LANES];
        if let Some(b) = init {
            acc.copy_from_slice(&b[o0..o0 + LANES]);
        }
        for (k, &xv) in xrow.iter().enumerate() {
            let wtile = &w[k * d_out + o0..k * d_out + o0 + LANES];
            for j in 0..LANES {
                acc[j] += xv * wtile[j];
            }
        }
        orow[o0..o0 + LANES].copy_from_slice(&acc);
        o0 += LANES;
    }
    if o0 < d_out {
        let rem = d_out - o0;
        let mut acc = [0.0f32; LANES];
        if let Some(b) = init {
            acc[..rem].copy_from_slice(&b[o0..]);
        }
        for (k, &xv) in xrow.iter().enumerate() {
            let wtail = &w[k * d_out + o0..k * d_out + d_out];
            for (a, &wv) in acc[..rem].iter_mut().zip(wtail) {
                *a += xv * wv;
            }
        }
        orow[o0..].copy_from_slice(&acc[..rem]);
    }
}

// ---------------------------------------------------------------------------
// Linear layers
// ---------------------------------------------------------------------------

/// `out[r] = relu?(x[r] @ w + b)` — `x (n, d_in)`, `w (d_in, d_out)`,
/// `b (d_out)`, `out (n, d_out)`. Rows are partitioned across threads;
/// each output element accumulates over `k` in ascending order inside a
/// [`LANES`]-wide register tile (bit-identical to the scalar form).
pub fn linear_fwd(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
    relu: bool,
    out: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(x.len(), n * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(b.len(), d_out);
    debug_assert_eq!(out.len(), n * d_out);
    par_rows(out, d_out, threads, |row0, rows| {
        for (i, orow) in rows.chunks_mut(d_out).enumerate() {
            let r = row0 + i;
            let xrow = &x[r * d_in..(r + 1) * d_in];
            row_matmul_tiled(xrow, w, d_out, Some(b), orow);
            if relu {
                for o in orow.iter_mut() {
                    if *o < 0.0 {
                        *o = 0.0;
                    }
                }
            }
        }
    });
}

/// `out[r] = x[r] @ w` — bias-free [`linear_fwd`] (the full-batch GCN's
/// propagated branch `adj @ (x @ w)` wants the product alone).
pub fn matmul_fwd(
    x: &[f32],
    w: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
    out: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(x.len(), n * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(out.len(), n * d_out);
    par_rows(out, d_out, threads, |row0, rows| {
        for (i, orow) in rows.chunks_mut(d_out).enumerate() {
            let r = row0 + i;
            let xrow = &x[r * d_in..(r + 1) * d_in];
            row_matmul_tiled(xrow, w, d_out, None, orow);
        }
    });
}

/// In-place ReLU: `x[i] = max(x[i], 0)`.
pub fn relu_inplace(x: &mut [f32], threads: usize) {
    if x.is_empty() {
        return;
    }
    par_rows(x, 1, threads, |_row0, part| {
        for v in part.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    });
}

/// Elementwise accumulate `dst[i] += src[i]`.
pub fn add_assign(dst: &mut [f32], src: &[f32], threads: usize) {
    debug_assert_eq!(dst.len(), src.len());
    if dst.is_empty() {
        return;
    }
    par_rows(dst, 1, threads, |row0, part| {
        for (i, v) in part.iter_mut().enumerate() {
            *v += src[row0 + i];
        }
    });
}

/// Elementwise `out[i] = c * x[i] + y[i]` (GIN's `(1 + ε)·h + A·h` and its
/// backward mirror).
pub fn scale_add(x: &[f32], c: f32, y: &[f32], out: &mut [f32], threads: usize) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    if out.is_empty() {
        return;
    }
    par_rows(out, 1, threads, |row0, part| {
        for (i, v) in part.iter_mut().enumerate() {
            let r = row0 + i;
            *v = c * x[r] + y[r];
        }
    });
}

/// Full sequential dot product over two equal-length buffers (GIN's scalar
/// `ε` gradient; single f32 accumulator in ascending index order).
pub fn dot_all(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// In-place ReLU backward: `dy[i] = 0` wherever the *post*-activation
/// `y[i] <= 0` (ties at exactly 0 get zero gradient, matching
/// `jax.nn.relu`'s subgradient choice at 0).
pub fn relu_bwd_mask(dy: &mut [f32], y: &[f32], threads: usize) {
    debug_assert_eq!(dy.len(), y.len());
    if dy.is_empty() {
        return;
    }
    par_rows(dy, 1, threads, |row0, part| {
        for (i, v) in part.iter_mut().enumerate() {
            if y[row0 + i] <= 0.0 {
                *v = 0.0;
            }
        }
    });
}

/// `dx (n, d_in) =|+= dz (n, d_out) @ wᵀ`. Rows of `dx` are partitioned;
/// each entry is a dot over `d_out` in ascending order. Entries are
/// computed [`LANES`] at a time — `LANES` independent accumulator chains
/// over the shared `dzrow` stream — which overlaps the FMA latency the
/// one-dot-at-a-time form serializes on, without touching any single
/// entry's reduction order. The `d_in % LANES` tail runs the same loop at
/// partial width.
pub fn matmul_wt(
    dz: &[f32],
    w: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
    accumulate: bool,
    dx: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(dz.len(), n * d_out);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(dx.len(), n * d_in);
    par_rows(dx, d_in, threads, |row0, rows| {
        for (i, xrow) in rows.chunks_mut(d_in).enumerate() {
            let r = row0 + i;
            let dzrow = &dz[r * d_out..(r + 1) * d_out];
            let mut k0 = 0;
            loop {
                let width = LANES.min(d_in - k0);
                if width == 0 {
                    break;
                }
                let mut acc = [0.0f32; LANES];
                for (o, &g) in dzrow.iter().enumerate() {
                    for (j, a) in acc[..width].iter_mut().enumerate() {
                        *a += g * w[(k0 + j) * d_out + o];
                    }
                }
                for (j, a) in acc[..width].iter().enumerate() {
                    if accumulate {
                        xrow[k0 + j] += *a;
                    } else {
                        xrow[k0 + j] = *a;
                    }
                }
                k0 += width;
            }
        }
    });
}

/// `dw (d_in, d_out) += xᵀ @ dz`. Rows of `dw` (the `d_in` axis) are
/// partitioned; each `dw[k]` row accumulates over batch rows in ascending
/// order, so repeated calls (one per layer application) accumulate
/// deterministically.
pub fn grad_w(
    x: &[f32],
    dz: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
    dw: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(x.len(), n * d_in);
    debug_assert_eq!(dz.len(), n * d_out);
    debug_assert_eq!(dw.len(), d_in * d_out);
    // Blocked over batch rows (L1 reuse of the strided x column and the
    // dz rows) and register-tiled over output columns. Per element the
    // accumulation is still `dw[k][o] += x[r][k]·dz[r][o]` for r
    // ascending 0..n, with the same `xv != 0.0` skip as the scalar form
    // (the skip is load-bearing for bit parity: adding a `−0.0` product
    // would flip a `−0.0` partial sum to `+0.0`).
    par_rows(dw, d_out, threads, |k0, rows| {
        for (i, drow) in rows.chunks_mut(d_out).enumerate() {
            let k = k0 + i;
            for r0 in (0..n).step_by(ROW_BLOCK) {
                let r1 = (r0 + ROW_BLOCK).min(n);
                let mut o0 = 0;
                loop {
                    let width = LANES.min(d_out - o0);
                    if width == 0 {
                        break;
                    }
                    let mut acc = [0.0f32; LANES];
                    acc[..width].copy_from_slice(&drow[o0..o0 + width]);
                    for r in r0..r1 {
                        let xv = x[r * d_in + k];
                        if xv != 0.0 {
                            let dztile = &dz[r * d_out + o0..r * d_out + o0 + width];
                            for (a, &g) in acc[..width].iter_mut().zip(dztile) {
                                *a += xv * g;
                            }
                        }
                    }
                    drow[o0..o0 + width].copy_from_slice(&acc[..width]);
                    o0 += width;
                }
            }
        }
    });
}

/// `db (d_out) += column sums of dz (n, d_out)`. Single-threaded row-order
/// accumulation (cheap, and trivially thread-count independent).
pub fn grad_b(dz: &[f32], n: usize, d_out: usize, db: &mut [f32]) {
    debug_assert_eq!(dz.len(), n * d_out);
    debug_assert_eq!(db.len(), d_out);
    for r in 0..n {
        let dzrow = &dz[r * d_out..(r + 1) * d_out];
        for (d, &g) in db.iter_mut().zip(dzrow) {
            *d += g;
        }
    }
}

// ---------------------------------------------------------------------------
// Mean aggregation / concat (GraphSAGE plumbing)
// ---------------------------------------------------------------------------

/// `agg (n, d) = mean over the middle axis of nbrs (n, k, d)`.
pub fn mean_rows_fwd(nbrs: &[f32], n: usize, k: usize, d: usize, agg: &mut [f32], threads: usize) {
    debug_assert_eq!(nbrs.len(), n * k * d);
    debug_assert_eq!(agg.len(), n * d);
    debug_assert!(k > 0);
    let inv = 1.0f32 / k as f32;
    par_rows(agg, d, threads, |row0, rows| {
        for (i, arow) in rows.chunks_mut(d).enumerate() {
            let r = row0 + i;
            arow.fill(0.0);
            for t in 0..k {
                let src = &nbrs[(r * k + t) * d..(r * k + t + 1) * d];
                for (a, &v) in arow.iter_mut().zip(src) {
                    *a += v;
                }
            }
            for a in arow.iter_mut() {
                *a *= inv;
            }
        }
    });
}

/// Backward of [`mean_rows_fwd`]:
/// `dnbrs[(r, t)] =|+= dagg[r] / k` for every `t`.
pub fn mean_rows_bwd(
    dagg: &[f32],
    n: usize,
    k: usize,
    d: usize,
    accumulate: bool,
    dnbrs: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(dagg.len(), n * d);
    debug_assert_eq!(dnbrs.len(), n * k * d);
    let inv = 1.0f32 / k as f32;
    // Partition over the (n) groups: each worker owns whole k*d blocks.
    par_rows(dnbrs, k * d, threads, |row0, groups| {
        for (i, group) in groups.chunks_mut(k * d).enumerate() {
            let r = row0 + i;
            let drow = &dagg[r * d..(r + 1) * d];
            for block in group.chunks_mut(d) {
                for (o, &g) in block.iter_mut().zip(drow) {
                    if accumulate {
                        *o += g * inv;
                    } else {
                        *o = g * inv;
                    }
                }
            }
        }
    });
}

/// Write `src (n, width)` into columns `[col0, col0+width)` of
/// `dst (n, d_dst)` (concat forward building block).
pub fn scatter_cols(
    src: &[f32],
    n: usize,
    d_dst: usize,
    col0: usize,
    width: usize,
    dst: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(src.len(), n * width);
    debug_assert_eq!(dst.len(), n * d_dst);
    debug_assert!(col0 + width <= d_dst);
    par_rows(dst, d_dst, threads, |row0, rows| {
        for (i, drow) in rows.chunks_mut(d_dst).enumerate() {
            let r = row0 + i;
            drow[col0..col0 + width].copy_from_slice(&src[r * width..(r + 1) * width]);
        }
    });
}

/// Read columns `[col0, col0+width)` of `src (n, d_src)` into
/// `dst (n, width)` (concat backward / split building block).
pub fn gather_cols(
    src: &[f32],
    n: usize,
    d_src: usize,
    col0: usize,
    width: usize,
    accumulate: bool,
    dst: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(src.len(), n * d_src);
    debug_assert_eq!(dst.len(), n * width);
    debug_assert!(col0 + width <= d_src);
    par_rows(dst, width, threads, |row0, rows| {
        for (i, drow) in rows.chunks_mut(width).enumerate() {
            let r = row0 + i;
            let srow = &src[r * d_src + col0..r * d_src + col0 + width];
            if accumulate {
                for (o, &v) in drow.iter_mut().zip(srow) {
                    *o += v;
                }
            } else {
                drow.copy_from_slice(srow);
            }
        }
    });
}

/// In-place per-column rescale: `x[r, k] *= scale[k]` over `x (n, d)`
/// (the light decoder's trainable `W0`).
pub fn scale_cols(x: &mut [f32], d: usize, scale: &[f32], threads: usize) {
    debug_assert_eq!(scale.len(), d);
    debug_assert_eq!(x.len() % d.max(1), 0);
    par_rows(x, d, threads, |_row0, rows| {
        for xrow in rows.chunks_mut(d) {
            for (v, &s) in xrow.iter_mut().zip(scale) {
                *v *= s;
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Codebook decoder kernels (paper §3.2: gather + sum over m codebooks)
// ---------------------------------------------------------------------------

/// Validate that every code element lies in `[0, c)`.
pub fn validate_codes(codes: &[i32], c: usize) -> Result<()> {
    for &v in codes {
        if v < 0 || v as usize >= c {
            return Err(Error::Shape(format!("code value {v} out of range [0, {c})")));
        }
    }
    Ok(())
}

/// `out[r] = Σ_j books[j, codes[r, j], :]` — `books (m, c, d_c)`,
/// `codes (n, m)` int32, `out (n, d_c)`. Caller must have validated codes.
pub fn codebook_fwd(
    books: &[f32],
    codes: &[i32],
    n: usize,
    m: usize,
    c: usize,
    d_c: usize,
    out: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(books.len(), m * c * d_c);
    debug_assert_eq!(codes.len(), n * m);
    debug_assert_eq!(out.len(), n * d_c);
    par_rows(out, d_c, threads, |row0, rows| {
        for (i, orow) in rows.chunks_mut(d_c).enumerate() {
            let r = row0 + i;
            orow.fill(0.0);
            for j in 0..m {
                let code = codes[r * m + j] as usize;
                let brow = &books[(j * c + code) * d_c..(j * c + code + 1) * d_c];
                for (o, &v) in orow.iter_mut().zip(brow) {
                    *o += v;
                }
            }
        }
    });
}

/// Backward of [`codebook_fwd`]:
/// `grad_books[j, codes[r, j], :] += dh[r, :]`. Threads partition the `m`
/// codebook positions (each position's scatter runs over rows in ascending
/// order), so accumulation order is independent of the thread count.
pub fn codebook_bwd(
    dh: &[f32],
    codes: &[i32],
    n: usize,
    m: usize,
    c: usize,
    d_c: usize,
    grad_books: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(dh.len(), n * d_c);
    debug_assert_eq!(codes.len(), n * m);
    debug_assert_eq!(grad_books.len(), m * c * d_c);
    par_rows(grad_books, c * d_c, threads, |j0, positions| {
        for (i, book) in positions.chunks_mut(c * d_c).enumerate() {
            let j = j0 + i;
            for r in 0..n {
                let code = codes[r * m + j] as usize;
                let drow = &dh[r * d_c..(r + 1) * d_c];
                let brow = &mut book[code * d_c..(code + 1) * d_c];
                for (b, &g) in brow.iter_mut().zip(drow) {
                    *b += g;
                }
            }
        }
    });
}

/// Fused §3.2 decode: `out[r] = relu?(b + (w0 ⊙ Σ_j books[j, codes[r, j], :]) @ w)`
/// in one pass per row — the code-indexed gather+sum feeds the first MLP
/// layer straight from a per-worker scratch row instead of materializing
/// the full `(n, d_c)` gathered matrix and re-reading it in a second
/// kernel. `w0` is the light decoder's optional per-column rescale.
///
/// Bit parity: each scratch element runs the exact ascending-`j` sum of
/// [`codebook_fwd`], then the exact in-place rescale of
/// [`super::ops::scale_cols`], and the matmul is the same
/// [`row_matmul_tiled`] core [`linear_fwd`] uses — so the fused output is
/// bit-identical to the unfused gather → scale → linear pipeline for
/// every thread count (asserted in the tests below).
///
/// Caller must have validated codes (see [`validate_codes`]).
pub fn codebook_linear_fwd(
    books: &[f32],
    codes: &[i32],
    n: usize,
    m: usize,
    c: usize,
    d_c: usize,
    w0: Option<&[f32]>,
    w: &[f32],
    b: &[f32],
    d_out: usize,
    relu: bool,
    out: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(books.len(), m * c * d_c);
    debug_assert_eq!(codes.len(), n * m);
    debug_assert_eq!(w.len(), d_c * d_out);
    debug_assert_eq!(b.len(), d_out);
    debug_assert_eq!(out.len(), n * d_out);
    if let Some(s) = w0 {
        debug_assert_eq!(s.len(), d_c);
    }
    par_rows(out, d_out, threads, |row0, rows| {
        // One gathered row of scratch per worker chunk, reused across all
        // of the chunk's rows — no per-row (let alone per-batch)
        // allocation on the serve hot path.
        let mut e = vec![0.0f32; d_c];
        for (i, orow) in rows.chunks_mut(d_out).enumerate() {
            let r = row0 + i;
            e.fill(0.0);
            for j in 0..m {
                let code = codes[r * m + j] as usize;
                let brow = &books[(j * c + code) * d_c..(j * c + code + 1) * d_c];
                for (o, &v) in e.iter_mut().zip(brow) {
                    *o += v;
                }
            }
            if let Some(s) = w0 {
                for (v, &sv) in e.iter_mut().zip(s) {
                    *v *= sv;
                }
            }
            row_matmul_tiled(&e, w, d_out, Some(b), orow);
            if relu {
                for o in orow.iter_mut() {
                    if *o < 0.0 {
                        *o = 0.0;
                    }
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Embedding-table kernels (NC baseline)
// ---------------------------------------------------------------------------

/// Validate that every id lies in `[0, n_table)`.
pub fn validate_ids(ids: &[i32], n_table: usize) -> Result<()> {
    for &v in ids {
        if v < 0 || v as usize >= n_table {
            return Err(Error::Shape(format!("node id {v} out of range [0, {n_table})")));
        }
    }
    Ok(())
}

/// `out[r] = table[ids[r]]` — `table (n_table, d)`, `out (n, d)`.
pub fn table_gather(
    table: &[f32],
    ids: &[i32],
    d: usize,
    out: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(out.len(), ids.len() * d);
    par_rows(out, d, threads, |row0, rows| {
        for (i, orow) in rows.chunks_mut(d).enumerate() {
            let id = ids[row0 + i] as usize;
            orow.copy_from_slice(&table[id * d..(id + 1) * d]);
        }
    });
}

/// Backward of [`table_gather`]: `grad[ids[r]] += dx[r]`. Threads
/// partition the *table* rows; every worker scans all batch rows in
/// ascending order and accumulates only the ids that land in its range —
/// deterministic for any thread count, no scatter races.
pub fn table_scatter_grad(
    dx: &[f32],
    ids: &[i32],
    d: usize,
    grad: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(dx.len(), ids.len() * d);
    debug_assert_eq!(grad.len() % d, 0);
    par_rows(grad, d, threads, |row0, rows| {
        let hi = row0 + rows.len() / d;
        for (r, &id) in ids.iter().enumerate() {
            let id = id as usize;
            if id >= row0 && id < hi {
                let grow = &mut rows[(id - row0) * d..(id - row0 + 1) * d];
                let drow = &dx[r * d..(r + 1) * d];
                for (g, &v) in grow.iter_mut().zip(drow) {
                    *g += v;
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Losses and heads
// ---------------------------------------------------------------------------

/// Softmax cross-entropy over `logits (n, c)` with integer `labels (n)`.
/// Returns the mean loss and writes `dlogits = (softmax − onehot) / n` —
/// exactly [`masked_softmax_ce`] with an all-ones mask (`Σ mask = n` and
/// `x · 1.0` are exact in f32, so the results are bit-identical to the
/// dedicated kernel this used to be).
pub fn softmax_ce(
    logits: &[f32],
    labels: &[i32],
    n: usize,
    c: usize,
    dlogits: &mut [f32],
    threads: usize,
) -> Result<f32> {
    let ones = vec![1.0f32; n];
    masked_softmax_ce(logits, labels, &ones, n, c, dlogits, threads)
}

/// Masked softmax cross-entropy (full-batch node classification, mirrors
/// `python/compile/gnn.py::masked_cross_entropy`): mean NLL over the rows
/// `mask` selects, `loss = Σ_r nll[r]·mask[r] / max(Σ_r mask[r], 1)`, with
/// `dlogits[r] = (softmax(logits[r]) − onehot(labels[r])) · mask[r] / M`.
/// Rows compute their own softmax in parallel; both reductions over rows
/// are single-threaded ascending sums.
pub fn masked_softmax_ce(
    logits: &[f32],
    labels: &[i32],
    mask: &[f32],
    n: usize,
    c: usize,
    dlogits: &mut [f32],
    threads: usize,
) -> Result<f32> {
    debug_assert_eq!(logits.len(), n * c);
    debug_assert_eq!(labels.len(), n);
    debug_assert_eq!(mask.len(), n);
    debug_assert_eq!(dlogits.len(), n * c);
    if n == 0 {
        return Err(Error::Shape("masked_softmax_ce needs a non-empty batch".into()));
    }
    for &l in labels {
        if l < 0 || l as usize >= c {
            return Err(Error::Shape(format!("label {l} out of range [0, {c})")));
        }
    }
    let mut msum = 0.0f32;
    for &w in mask {
        msum += w;
    }
    let inv = 1.0f32 / msum.max(1.0);
    let mut nll = vec![0.0f32; n];
    let fill_rows = |row0: usize, drows: &mut [f32], nrows: &mut [f32]| {
        for (i, drow) in drows.chunks_mut(c).enumerate() {
            let r = row0 + i;
            let lrow = &logits[r * c..(r + 1) * c];
            let mut mx = f32::NEG_INFINITY;
            for &v in lrow {
                if v > mx {
                    mx = v;
                }
            }
            let mut z = 0.0f32;
            for (d, &v) in drow.iter_mut().zip(lrow) {
                let e = (v - mx).exp();
                *d = e;
                z += e;
            }
            let label = labels[r] as usize;
            nrows[i] = (z.ln() + mx - lrow[label]) * mask[r];
            let scale = mask[r] * inv;
            for (j, d) in drow.iter_mut().enumerate() {
                let p = *d / z;
                *d = (p - if j == label { 1.0 } else { 0.0 }) * scale;
            }
        }
    };
    let workers = threads.clamp(1, n);
    if workers == 1 {
        fill_rows(0, dlogits, &mut nll);
    } else {
        let chunk = n.div_ceil(workers);
        let fill_rows = &fill_rows;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = dlogits
            .chunks_mut(chunk * c)
            .zip(nll.chunks_mut(chunk))
            .enumerate()
            .map(|(w, (drows, nrows))| {
                Box::new(move || fill_rows(w * chunk, drows, nrows))
                    as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        join_all(jobs);
    }
    let mut loss = 0.0f32;
    for &v in &nll {
        loss += v;
    }
    Ok(loss * inv)
}

/// Mean-squared-error loss `mean((pred − target)²)` over all elements.
/// Writes `dpred = 2 (pred − target) / len`. Loss reduction is a
/// single-threaded ascending sum.
pub fn mse(pred: &[f32], target: &[f32], dpred: &mut [f32], threads: usize) -> f32 {
    debug_assert_eq!(pred.len(), target.len());
    debug_assert_eq!(pred.len(), dpred.len());
    let len = pred.len();
    let inv = 1.0f32 / len as f32;
    par_rows(dpred, 1, threads, |row0, part| {
        for (i, d) in part.iter_mut().enumerate() {
            let r = row0 + i;
            *d = 2.0 * (pred[r] - target[r]) * inv;
        }
    });
    let mut loss = 0.0f32;
    for (&p, &t) in pred.iter().zip(target) {
        let e = p - t;
        loss += e * e;
    }
    loss * inv
}

/// Row-wise dot products: `out[r] = a[r] · b[r]` over `(n, d)` inputs.
pub fn dot_rows(a: &[f32], b: &[f32], n: usize, d: usize, out: &mut [f32], threads: usize) {
    debug_assert_eq!(a.len(), n * d);
    debug_assert_eq!(b.len(), n * d);
    debug_assert_eq!(out.len(), n);
    par_rows(out, 1, threads, |row0, part| {
        for (i, o) in part.iter_mut().enumerate() {
            let r = row0 + i;
            let ar = &a[r * d..(r + 1) * d];
            let br = &b[r * d..(r + 1) * d];
            let mut acc = 0.0f32;
            for (&x, &y) in ar.iter().zip(br) {
                acc += x * y;
            }
            *o = acc;
        }
    });
}

/// Numerically stable `softplus(x) = ln(1 + eˣ)`.
#[inline]
pub fn softplus(x: f32) -> f32 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

/// Numerically stable logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// BPR ranking loss `mean_e softplus(−(pos[e] − neg[e]))` (§4's
/// dot-product link head). Writes the score gradients. Single-threaded —
/// `n` is a batch size.
pub fn bpr_loss(pos: &[f32], neg: &[f32], dpos: &mut [f32], dneg: &mut [f32]) -> f32 {
    debug_assert_eq!(pos.len(), neg.len());
    let n = pos.len();
    let inv = 1.0f32 / n as f32;
    let mut loss = 0.0f32;
    for e in 0..n {
        let x = pos[e] - neg[e];
        loss += softplus(-x);
        let g = -sigmoid(-x) * inv;
        dpos[e] = g;
        dneg[e] = -g;
    }
    loss * inv
}

/// BCE-with-logits over a positive/negative score pair (full-batch link
/// prediction, mirrors `python/compile/gnn.py::bce_link_loss`):
/// `loss = mean_e softplus(−pos[e]) + mean_e softplus(neg[e])`. Writes the
/// score gradients. Single-threaded — `e` is an edge-batch size.
pub fn bce_pair_loss(pos: &[f32], neg: &[f32], dpos: &mut [f32], dneg: &mut [f32]) -> f32 {
    debug_assert_eq!(pos.len(), neg.len());
    debug_assert_eq!(pos.len(), dpos.len());
    debug_assert_eq!(pos.len(), dneg.len());
    let n = pos.len();
    let inv = 1.0f32 / n as f32;
    let mut loss_pos = 0.0f32;
    let mut loss_neg = 0.0f32;
    for e in 0..n {
        loss_pos += softplus(-pos[e]);
        loss_neg += softplus(neg[e]);
        dpos[e] = -sigmoid(-pos[e]) * inv;
        dneg[e] = sigmoid(neg[e]) * inv;
    }
    loss_pos * inv + loss_neg * inv
}

// ---------------------------------------------------------------------------
// Forward-only losses (inference / serving parity)
// ---------------------------------------------------------------------------
//
// The [`super::infer`] path must report the same loss value as the fused
// train step without touching any gradient buffer. Each `*_loss` /
// `*_value` variant below repeats the exact per-element math and the exact
// single-threaded ascending reductions of its training twin, so the value
// is bit-identical — asserted by the tests at the bottom of this file.

/// Loss of [`masked_softmax_ce`] without the `dlogits` write. Same per-row
/// softmax math (max, then `exp` accumulated in ascending column order)
/// and the same sequential mask/NLL sums, so the value is bit-identical to
/// the training kernel's for every thread count.
pub fn masked_softmax_ce_loss(
    logits: &[f32],
    labels: &[i32],
    mask: &[f32],
    n: usize,
    c: usize,
    threads: usize,
) -> Result<f32> {
    debug_assert_eq!(logits.len(), n * c);
    debug_assert_eq!(labels.len(), n);
    debug_assert_eq!(mask.len(), n);
    if n == 0 {
        return Err(Error::Shape("masked_softmax_ce needs a non-empty batch".into()));
    }
    for &l in labels {
        if l < 0 || l as usize >= c {
            return Err(Error::Shape(format!("label {l} out of range [0, {c})")));
        }
    }
    let mut msum = 0.0f32;
    for &w in mask {
        msum += w;
    }
    let inv = 1.0f32 / msum.max(1.0);
    let mut nll = vec![0.0f32; n];
    par_rows(&mut nll, 1, threads, |row0, part| {
        for (i, o) in part.iter_mut().enumerate() {
            let r = row0 + i;
            let lrow = &logits[r * c..(r + 1) * c];
            let mut mx = f32::NEG_INFINITY;
            for &v in lrow {
                if v > mx {
                    mx = v;
                }
            }
            let mut z = 0.0f32;
            for &v in lrow {
                z += (v - mx).exp();
            }
            *o = (z.ln() + mx - lrow[labels[r] as usize]) * mask[r];
        }
    });
    let mut loss = 0.0f32;
    for &v in &nll {
        loss += v;
    }
    Ok(loss * inv)
}

/// Loss of [`softmax_ce`] without gradients — [`masked_softmax_ce_loss`]
/// with an all-ones mask, mirroring how the training kernels relate.
pub fn softmax_ce_loss(
    logits: &[f32],
    labels: &[i32],
    n: usize,
    c: usize,
    threads: usize,
) -> Result<f32> {
    let ones = vec![1.0f32; n];
    masked_softmax_ce_loss(logits, labels, &ones, n, c, threads)
}

/// Loss of [`mse`] without the `dpred` write (same sequential ascending
/// sum, same final scale — bit-identical).
pub fn mse_loss(pred: &[f32], target: &[f32]) -> f32 {
    debug_assert_eq!(pred.len(), target.len());
    let inv = 1.0f32 / pred.len() as f32;
    let mut loss = 0.0f32;
    for (&p, &t) in pred.iter().zip(target) {
        let e = p - t;
        loss += e * e;
    }
    loss * inv
}

/// Loss of [`bpr_loss`] without the score gradients (same per-pair
/// softplus, same ascending sum — bit-identical).
pub fn bpr_loss_value(pos: &[f32], neg: &[f32]) -> f32 {
    debug_assert_eq!(pos.len(), neg.len());
    let inv = 1.0f32 / pos.len() as f32;
    let mut loss = 0.0f32;
    for e in 0..pos.len() {
        let x = pos[e] - neg[e];
        loss += softplus(-x);
    }
    loss * inv
}

/// Loss of [`bce_pair_loss`] without the score gradients (same two
/// ascending sums combined the same way — bit-identical).
pub fn bce_pair_loss_value(pos: &[f32], neg: &[f32]) -> f32 {
    debug_assert_eq!(pos.len(), neg.len());
    let n = pos.len();
    let inv = 1.0f32 / n as f32;
    let mut loss_pos = 0.0f32;
    let mut loss_neg = 0.0f32;
    for e in 0..n {
        loss_pos += softplus(-pos[e]);
        loss_neg += softplus(neg[e]);
    }
    loss_pos * inv + loss_neg * inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_fwd_matches_manual() {
        // x (2,3) @ w (3,2) + b
        let x = vec![1.0, 2.0, 3.0, -1.0, 0.5, 2.0];
        let w = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let b = vec![0.5, -0.5];
        let mut out = vec![0.0; 4];
        linear_fwd(&x, &w, &b, 2, 3, 2, false, &mut out, 1);
        // row0: [1+3+0.5, 2+3-0.5] = [4.5, 4.5]
        // row1: [-1+2+0.5, 0.5+2-0.5] = [1.5, 2.0]
        assert_eq!(out, vec![4.5, 4.5, 1.5, 2.0]);
        let mut out_relu = vec![0.0; 4];
        let b_neg = vec![-10.0, 0.0];
        linear_fwd(&x, &w, &b_neg, 2, 3, 2, true, &mut out_relu, 3);
        assert_eq!(out_relu, vec![0.0, 5.0, 0.0, 2.5]);
    }

    #[test]
    fn matmul_wt_and_grad_w_match_manual() {
        // y = x @ w; dz given; dx = dz @ wT; dw = xT dz.
        let x = vec![1.0, 2.0, 3.0, 4.0]; // (2,2)
        let w = vec![1.0, -1.0, 0.5, 2.0]; // (2,2)
        let dz = vec![1.0, 1.0, 0.0, 2.0]; // (2,2)
        let mut dx = vec![0.0; 4];
        matmul_wt(&dz, &w, 2, 2, 2, false, &mut dx, 2);
        // dx[0] = [1*1 + 1*(-1), 1*0.5 + 1*2] = [0, 2.5]
        // dx[1] = [0*1 + 2*(-1), 0*0.5 + 2*2] = [-2, 4]
        assert_eq!(dx, vec![0.0, 2.5, -2.0, 4.0]);
        let mut dw = vec![0.0; 4];
        grad_w(&x, &dz, 2, 2, 2, &mut dw, 2);
        // dw[k][j] = sum_r x[r][k] dz[r][j]
        // dw[0] = [1*1+3*0, 1*1+3*2] = [1, 7]; dw[1] = [2*1+4*0, 2*1+4*2] = [2, 10]
        assert_eq!(dw, vec![1.0, 7.0, 2.0, 10.0]);
        let mut db = vec![0.0; 2];
        grad_b(&dz, 2, 2, &mut db);
        assert_eq!(db, vec![1.0, 3.0]);
    }

    #[test]
    fn mean_rows_roundtrip() {
        // nbrs (1, 2, 3)
        let nbrs = vec![1.0, 2.0, 3.0, 3.0, 4.0, 5.0];
        let mut agg = vec![0.0; 3];
        mean_rows_fwd(&nbrs, 1, 2, 3, &mut agg, 1);
        assert_eq!(agg, vec![2.0, 3.0, 4.0]);
        let dagg = vec![2.0, 4.0, 6.0];
        let mut dn = vec![0.0; 6];
        mean_rows_bwd(&dagg, 1, 2, 3, false, &mut dn, 1);
        assert_eq!(dn, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
        mean_rows_bwd(&dagg, 1, 2, 3, true, &mut dn, 4);
        assert_eq!(dn, vec![2.0, 4.0, 6.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn concat_cols_roundtrip() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // (2,2)
        let b = vec![5.0, 6.0]; // (2,1)
        let mut cat = vec![0.0; 6]; // (2,3)
        scatter_cols(&a, 2, 3, 0, 2, &mut cat, 1);
        scatter_cols(&b, 2, 3, 2, 1, &mut cat, 1);
        assert_eq!(cat, vec![1.0, 2.0, 5.0, 3.0, 4.0, 6.0]);
        let mut back_a = vec![0.0; 4];
        gather_cols(&cat, 2, 3, 0, 2, false, &mut back_a, 2);
        assert_eq!(back_a, a);
        gather_cols(&cat, 2, 3, 0, 2, true, &mut back_a, 2);
        assert_eq!(back_a, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn codebook_fwd_bwd_match_manual() {
        // m=2 positions, c=2 rows each, d_c=2.
        let books = vec![
            1.0, 0.0, // book 0, code 0
            0.0, 1.0, // book 0, code 1
            2.0, 2.0, // book 1, code 0
            3.0, -1.0, // book 1, code 1
        ];
        let codes = vec![0, 1, 1, 0]; // rows: [b0c0 + b1c1], [b0c1 + b1c0]
        assert!(validate_codes(&codes, 2).is_ok());
        assert!(validate_codes(&[2], 2).is_err());
        assert!(validate_codes(&[-1], 2).is_err());
        let mut out = vec![0.0; 4];
        codebook_fwd(&books, &codes, 2, 2, 2, 2, &mut out, 1);
        assert_eq!(out, vec![4.0, -1.0, 2.0, 3.0]);
        let dh = vec![1.0, 2.0, 3.0, 4.0];
        let mut gb = vec![0.0; 8];
        codebook_bwd(&dh, &codes, 2, 2, 2, 2, &mut gb, 2);
        // book0 code0 += dh row0; book0 code1 += dh row1;
        // book1 code1 += dh row0; book1 code0 += dh row1.
        assert_eq!(gb, vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 1.0, 2.0]);
    }

    #[test]
    fn table_gather_scatter_match_manual() {
        let table = vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]; // (3,2)
        let ids = vec![2, 0, 2];
        assert!(validate_ids(&ids, 3).is_ok());
        assert!(validate_ids(&[3], 3).is_err());
        let mut out = vec![0.0; 6];
        table_gather(&table, &ids, 2, &mut out, 2);
        assert_eq!(out, vec![2.0, 2.0, 0.0, 0.0, 2.0, 2.0]);
        let dx = vec![1.0, 1.0, 5.0, 5.0, 2.0, 2.0];
        let mut grad = vec![0.0; 6];
        table_scatter_grad(&dx, &ids, 2, &mut grad, 3);
        assert_eq!(grad, vec![5.0, 5.0, 0.0, 0.0, 3.0, 3.0]);
    }

    #[test]
    fn softmax_ce_uniform_logits() {
        // Uniform logits over 4 classes: loss = ln 4, grad = (1/4 - onehot)/n.
        let logits = vec![0.0f32; 8];
        let labels = vec![1, 3];
        let mut d = vec![0.0f32; 8];
        let loss = softmax_ce(&logits, &labels, 2, 4, &mut d, 1).unwrap();
        assert!((loss - (4.0f32).ln()).abs() < 1e-6, "{loss}");
        assert!((d[0] - 0.125).abs() < 1e-6);
        assert!((d[1] + 0.375).abs() < 1e-6);
        assert!((d[7] + 0.375).abs() < 1e-6);
        assert!(softmax_ce(&logits, &[4, 0], 2, 4, &mut d, 1).is_err());
    }

    #[test]
    fn mse_matches_manual() {
        let pred = vec![1.0, 2.0];
        let target = vec![0.0, 4.0];
        let mut d = vec![0.0; 2];
        let loss = mse(&pred, &target, &mut d, 1);
        assert!((loss - (1.0 + 4.0) / 2.0).abs() < 1e-6);
        assert_eq!(d, vec![1.0, -2.0]);
    }

    #[test]
    fn bpr_loss_shape() {
        let pos = vec![2.0f32, 0.0];
        let neg = vec![0.0f32, 2.0];
        let mut dp = vec![0.0; 2];
        let mut dn = vec![0.0; 2];
        let loss = bpr_loss(&pos, &neg, &mut dp, &mut dn);
        let expect = (softplus(-2.0) + softplus(2.0)) / 2.0;
        assert!((loss - expect).abs() < 1e-6);
        assert!(dp[0] < 0.0 && dn[0] > 0.0);
        assert!((dp[0] + dn[0]).abs() < 1e-7);
        // Wrong-ordered pair pulls harder than the satisfied one.
        assert!(dp[1].abs() > dp[0].abs());
    }

    #[test]
    fn matmul_fwd_is_biasless_linear() {
        let x = vec![1.0, 2.0, 3.0, -1.0, 0.5, 2.0];
        let w = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let mut out = vec![0.0; 4];
        matmul_fwd(&x, &w, 2, 3, 2, &mut out, 2);
        assert_eq!(out, vec![4.0, 5.0, 1.0, 2.5]);
    }

    #[test]
    fn elementwise_helpers_match_manual() {
        let mut x = vec![-1.0f32, 0.0, 2.0];
        relu_inplace(&mut x, 2);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
        let mut dst = vec![1.0f32, 2.0, 3.0];
        add_assign(&mut dst, &[0.5, -2.0, 1.0], 2);
        assert_eq!(dst, vec![1.5, 0.0, 4.0]);
        let mut out = vec![0.0f32; 3];
        scale_add(&[1.0, 2.0, 3.0], 1.5, &[10.0, 20.0, 30.0], &mut out, 2);
        assert_eq!(out, vec![11.5, 23.0, 34.5]);
        assert_eq!(dot_all(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn masked_softmax_ce_respects_mask() {
        // Uniform logits, 2 rows × 4 classes, only row 0 masked in.
        let logits = vec![0.0f32; 8];
        let labels = vec![1, 3];
        let mask = vec![1.0f32, 0.0];
        let mut d = vec![0.0f32; 8];
        let loss = masked_softmax_ce(&logits, &labels, &mask, 2, 4, &mut d, 1).unwrap();
        assert!((loss - (4.0f32).ln()).abs() < 1e-6, "{loss}");
        // Row 0 gradient = (1/4 - onehot) / 1; row 1 gradient = 0.
        assert!((d[0] - 0.25).abs() < 1e-6);
        assert!((d[1] + 0.75).abs() < 1e-6);
        assert!(d[4..].iter().all(|&g| g == 0.0));
        // All-zero mask: denominator clamps to 1, loss 0, grads 0.
        let zero_mask = vec![0.0f32; 2];
        let loss = masked_softmax_ce(&logits, &labels, &zero_mask, 2, 4, &mut d, 2).unwrap();
        assert_eq!(loss, 0.0);
        assert!(d.iter().all(|&g| g == 0.0));
        assert!(masked_softmax_ce(&logits, &[4, 0], &mask, 2, 4, &mut d, 1).is_err());
        // Thread invariance (bitwise).
        let logits: Vec<f32> = (0..12).map(|i| (i as f32 * 0.7).sin()).collect();
        let labels = vec![0, 2, 1];
        let mask = vec![1.0, 0.0, 1.0];
        let mut d1 = vec![0.0f32; 12];
        let mut d7 = vec![0.0f32; 12];
        let l1 = masked_softmax_ce(&logits, &labels, &mask, 3, 4, &mut d1, 1).unwrap();
        let l7 = masked_softmax_ce(&logits, &labels, &mask, 3, 4, &mut d7, 7).unwrap();
        assert_eq!(l1.to_bits(), l7.to_bits());
        assert!(d1.iter().zip(&d7).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn bce_pair_loss_shape_and_grads() {
        let pos = vec![2.0f32, -1.0];
        let neg = vec![0.0f32, 1.0];
        let mut dp = vec![0.0; 2];
        let mut dn = vec![0.0; 2];
        let loss = bce_pair_loss(&pos, &neg, &mut dp, &mut dn);
        let expect = (softplus(-2.0) + softplus(1.0)) / 2.0 + (softplus(0.0) + softplus(1.0)) / 2.0;
        assert!((loss - expect).abs() < 1e-6, "{loss} vs {expect}");
        // Positive scores are pushed up (negative gradient), negatives down.
        assert!(dp.iter().all(|&g| g < 0.0));
        assert!(dn.iter().all(|&g| g > 0.0));
        // Central finite difference on pos[1].
        let eps = 1e-3f32;
        let f = |p1: f32| -> f32 {
            let mut a = vec![0.0; 2];
            let mut b = vec![0.0; 2];
            bce_pair_loss(&[2.0, p1], &neg, &mut a, &mut b)
        };
        let fd = (f(-1.0 + eps) - f(-1.0 - eps)) / (2.0 * eps);
        assert!((fd - dp[1]).abs() < 1e-3, "fd {fd} vs {}", dp[1]);
    }

    /// Straight-line scalar references for the tiled kernels — the exact
    /// pre-tiling loops. The tiled forms must match them bit-for-bit at
    /// every width (full tiles, partial tail, width < LANES).
    mod reference {
        pub fn linear_fwd(
            x: &[f32],
            w: &[f32],
            b: &[f32],
            n: usize,
            d_in: usize,
            d_out: usize,
            relu: bool,
            out: &mut [f32],
        ) {
            for r in 0..n {
                let orow = &mut out[r * d_out..(r + 1) * d_out];
                orow.copy_from_slice(b);
                for k in 0..d_in {
                    let xv = x[r * d_in + k];
                    for (o, &wv) in orow.iter_mut().zip(&w[k * d_out..(k + 1) * d_out]) {
                        *o += xv * wv;
                    }
                }
                if relu {
                    for o in orow.iter_mut() {
                        if *o < 0.0 {
                            *o = 0.0;
                        }
                    }
                }
            }
        }

        pub fn matmul_wt(
            dz: &[f32],
            w: &[f32],
            n: usize,
            d_in: usize,
            d_out: usize,
            accumulate: bool,
            dx: &mut [f32],
        ) {
            for r in 0..n {
                let dzrow = &dz[r * d_out..(r + 1) * d_out];
                for k in 0..d_in {
                    let mut acc = 0.0f32;
                    for (&g, &wv) in dzrow.iter().zip(&w[k * d_out..(k + 1) * d_out]) {
                        acc += g * wv;
                    }
                    if accumulate {
                        dx[r * d_in + k] += acc;
                    } else {
                        dx[r * d_in + k] = acc;
                    }
                }
            }
        }

        pub fn grad_w(x: &[f32], dz: &[f32], n: usize, d_in: usize, d_out: usize, dw: &mut [f32]) {
            for k in 0..d_in {
                let drow = &mut dw[k * d_out..(k + 1) * d_out];
                for r in 0..n {
                    let xv = x[r * d_in + k];
                    if xv != 0.0 {
                        for (d, &g) in drow.iter_mut().zip(&dz[r * d_out..(r + 1) * d_out]) {
                            *d += xv * g;
                        }
                    }
                }
            }
        }
    }

    fn lcg(seed: u64) -> impl FnMut() -> f32 {
        let mut state = seed;
        move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        }
    }

    #[test]
    fn tiled_kernels_match_scalar_reference_at_all_tail_widths() {
        // Widths straddling the LANES=8 tile: below one lane, exact
        // multiples, and every interesting remainder. n=129 also makes
        // grad_w's ROW_BLOCK=64 blocking hit a partial final block.
        for &(d_in, d_out) in
            &[(1usize, 1usize), (3, 5), (8, 8), (7, 9), (11, 16), (16, 23), (9, 24), (5, 31)]
        {
            let n = 129;
            let mut next = lcg(0x5eed ^ (d_in * 100 + d_out) as u64);
            let x: Vec<f32> = (0..n * d_in).map(|_| next()).collect();
            let w: Vec<f32> = (0..d_in * d_out).map(|_| next()).collect();
            let b: Vec<f32> = (0..d_out).map(|_| next()).collect();
            let dz: Vec<f32> = (0..n * d_out).map(|_| next()).collect();

            let mut want = vec![0.0f32; n * d_out];
            reference::linear_fwd(&x, &w, &b, n, d_in, d_out, true, &mut want);
            let mut got = vec![0.0f32; n * d_out];
            linear_fwd(&x, &w, &b, n, d_in, d_out, true, &mut got, 1);
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "linear_fwd tail mismatch at d_in={d_in} d_out={d_out}"
            );
            let mut got0 = vec![0.0f32; n * d_out];
            matmul_fwd(&x, &w, n, d_in, d_out, &mut got0, 1);
            let zeros = vec![0.0f32; d_out];
            let mut want0 = vec![0.0f32; n * d_out];
            reference::linear_fwd(&x, &w, &zeros, n, d_in, d_out, false, &mut want0);
            assert!(got0.iter().zip(&want0).all(|(a, b)| a.to_bits() == b.to_bits()));

            let mut want_dx: Vec<f32> = (0..n * d_in).map(|_| next()).collect();
            let mut got_dx = want_dx.clone();
            reference::matmul_wt(&dz, &w, n, d_in, d_out, true, &mut want_dx);
            matmul_wt(&dz, &w, n, d_in, d_out, true, &mut got_dx, 1);
            assert!(
                got_dx.iter().zip(&want_dx).all(|(a, b)| a.to_bits() == b.to_bits()),
                "matmul_wt tail mismatch at d_in={d_in} d_out={d_out}"
            );

            // Sprinkle exact zeros into x so the xv != 0.0 skip is hit.
            let xz: Vec<f32> =
                x.iter().enumerate().map(|(i, &v)| if i % 7 == 0 { 0.0 } else { v }).collect();
            let mut want_dw = vec![0.0f32; d_in * d_out];
            let mut got_dw = vec![0.0f32; d_in * d_out];
            reference::grad_w(&xz, &dz, n, d_in, d_out, &mut want_dw);
            grad_w(&xz, &dz, n, d_in, d_out, &mut got_dw, 1);
            assert!(
                got_dw.iter().zip(&want_dw).all(|(a, b)| a.to_bits() == b.to_bits()),
                "grad_w tail mismatch at d_in={d_in} d_out={d_out}"
            );
        }
    }

    #[test]
    fn fused_codebook_linear_matches_unfused_pipeline_bitwise() {
        // fused gather+scale+matmul vs codebook_fwd → scale_cols →
        // linear_fwd, exact bytes, with and without w0/relu, across
        // thread counts and a non-multiple-of-LANES d_out.
        let (n, m, c, d_c, d_out) = (23usize, 4usize, 6usize, 13usize, 11usize);
        let mut next = lcg(42);
        let books: Vec<f32> = (0..m * c * d_c).map(|_| next()).collect();
        let codes: Vec<i32> = (0..n * m).map(|i| ((i * 31 + 7) % c) as i32).collect();
        let w: Vec<f32> = (0..d_c * d_out).map(|_| next()).collect();
        let b: Vec<f32> = (0..d_out).map(|_| next()).collect();
        let w0: Vec<f32> = (0..d_c).map(|_| next() + 1.0).collect();
        validate_codes(&codes, c).unwrap();
        for w0_opt in [None, Some(&w0[..])] {
            for relu in [false, true] {
                let mut gathered = vec![0.0f32; n * d_c];
                codebook_fwd(&books, &codes, n, m, c, d_c, &mut gathered, 1);
                if let Some(s) = w0_opt {
                    scale_cols(&mut gathered, d_c, s, 1);
                }
                let mut want = vec![0.0f32; n * d_out];
                linear_fwd(&gathered, &w, &b, n, d_c, d_out, relu, &mut want, 1);
                for threads in [1usize, 2, 8] {
                    let mut got = vec![0.0f32; n * d_out];
                    codebook_linear_fwd(
                        &books, &codes, n, m, c, d_c, w0_opt, &w, &b, d_out, relu, &mut got,
                        threads,
                    );
                    assert!(
                        got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "fused decode mismatch: w0={} relu={relu} threads={threads}",
                        w0_opt.is_some()
                    );
                }
            }
        }
    }

    #[test]
    fn kernels_thread_count_invariant() {
        // Random-ish data; every kernel must produce identical bits for
        // threads in {1, 2, 7}.
        let n = 37;
        let d_in = 11;
        let d_out = 5;
        let mut state = 1u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        let x: Vec<f32> = (0..n * d_in).map(|_| next()).collect();
        let w: Vec<f32> = (0..d_in * d_out).map(|_| next()).collect();
        let b: Vec<f32> = (0..d_out).map(|_| next()).collect();
        let dz: Vec<f32> = (0..n * d_out).map(|_| next()).collect();
        let mut base_out = vec![0.0; n * d_out];
        let mut base_dx = vec![0.0; n * d_in];
        let mut base_dw = vec![0.0; d_in * d_out];
        linear_fwd(&x, &w, &b, n, d_in, d_out, true, &mut base_out, 1);
        matmul_wt(&dz, &w, n, d_in, d_out, false, &mut base_dx, 1);
        grad_w(&x, &dz, n, d_in, d_out, &mut base_dw, 1);
        for threads in [2usize, 7, 8] {
            let mut out = vec![0.0; n * d_out];
            let mut dx = vec![0.0; n * d_in];
            let mut dw = vec![0.0; d_in * d_out];
            linear_fwd(&x, &w, &b, n, d_in, d_out, true, &mut out, threads);
            matmul_wt(&dz, &w, n, d_in, d_out, false, &mut dx, threads);
            grad_w(&x, &dz, n, d_in, d_out, &mut dw, threads);
            assert!(out.iter().zip(&base_out).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert!(dx.iter().zip(&base_dx).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert!(dw.iter().zip(&base_dw).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn forward_only_losses_match_training_kernels_bitwise() {
        let (n, c) = (13usize, 5usize);
        let mut state = 9u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        let logits: Vec<f32> = (0..n * c).map(|_| next() * 3.0).collect();
        let labels: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();
        let mask: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
        for threads in [1usize, 2, 8] {
            let mut d = vec![0.0f32; n * c];
            let full = masked_softmax_ce(&logits, &labels, &mask, n, c, &mut d, threads).unwrap();
            let fwd = masked_softmax_ce_loss(&logits, &labels, &mask, n, c, threads).unwrap();
            assert_eq!(full.to_bits(), fwd.to_bits(), "masked, threads={threads}");
            let mut d = vec![0.0f32; n * c];
            let full = softmax_ce(&logits, &labels, n, c, &mut d, threads).unwrap();
            let fwd = softmax_ce_loss(&logits, &labels, n, c, threads).unwrap();
            assert_eq!(full.to_bits(), fwd.to_bits(), "unmasked, threads={threads}");
        }
        let mut bad_labels = labels.clone();
        bad_labels[2] = 9;
        assert!(masked_softmax_ce_loss(&logits, &bad_labels, &mask, n, c, 1).is_err());

        let pred: Vec<f32> = (0..40).map(|_| next()).collect();
        let target: Vec<f32> = (0..40).map(|_| next()).collect();
        let mut dpred = vec![0.0f32; 40];
        assert_eq!(mse(&pred, &target, &mut dpred, 2).to_bits(), mse_loss(&pred, &target).to_bits());

        let pos: Vec<f32> = (0..17).map(|_| next() * 2.0).collect();
        let neg: Vec<f32> = (0..17).map(|_| next() * 2.0).collect();
        let (mut dp, mut dn) = (vec![0.0f32; 17], vec![0.0f32; 17]);
        assert_eq!(
            bpr_loss(&pos, &neg, &mut dp, &mut dn).to_bits(),
            bpr_loss_value(&pos, &neg).to_bits()
        );
        assert_eq!(
            bce_pair_loss(&pos, &neg, &mut dp, &mut dn).to_bits(),
            bce_pair_loss_value(&pos, &neg).to_bits()
        );
    }
}
