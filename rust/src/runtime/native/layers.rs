//! Reusable layer blocks for the native backend — the machinery PR 2 kept
//! inside `sage.rs`, extracted so the minibatch SAGE encoder and the
//! full-batch GNN grid ([`super::gnn`]) compose the same pieces:
//!
//! - [`FeatSource`]: the feature front-end (§3.2 code-dependent decoder, or
//!   an explicit `embed.table` for the NC baseline), in per-row-set form
//!   (minibatch fan-out tensors) and whole-graph form (full batch);
//! - [`LinearIdx`]: one resolved linear layer (`x @ w + b`, optional ReLU)
//!   with its hand-derived backward;
//! - [`spmm_par`]: deterministic parallel sparse propagation `A @ X` over
//!   [`Csr`], threads partitioning output rows.
//!
//! Everything follows the determinism rule of [`super::ops`]: threads only
//! partition output elements, reductions stay sequential per element.
#![allow(clippy::too_many_arguments)]

use std::sync::{Arc, OnceLock};

use crate::runtime::{Manifest, Tensor};
use crate::sparse::Csr;
use crate::{Error, Result};

use super::decoder::{self, find_param, DecCache, DecoderDims, DecoderIdx};
use super::hashemb::{self, HashCache, HashEmbDims, HashEmbIdx, HashKind, Ids};
use super::ops;
use super::par::par_rows;
use super::scratch::StepScratch;

// ---------------------------------------------------------------------------
// Feature front-end
// ---------------------------------------------------------------------------

/// Feature front-end: decoder over integer codes, an explicit
/// `embed.table` (the NC baseline), or one of the hash-embedding family
/// ([`super::hashemb`]: multihash / bloom / poshash over node ids).
pub enum FeatSource {
    Decoder { dims: DecoderDims, idx: DecoderIdx },
    Table { idx: usize, n: usize, d: usize },
    HashEmb {
        dims: HashEmbDims,
        idx: HashEmbIdx,
        /// Degree-rank bucket map for poshash, bound once per model like
        /// the full-batch adjacency (see [`FeatSource::bind_pos_map`]);
        /// unused (never set) for multihash/bloom.
        pos_map: OnceLock<Arc<Vec<u32>>>,
    },
}

/// A feature matrix produced by the inference-only front-end: owned for
/// decoded codes, borrowed straight from the parameter buffer for the NC
/// full-batch table (no gather, no copy).
pub enum Feats<'a> {
    Owned(Vec<f32>),
    Borrowed(&'a [f32]),
}

impl Feats<'_> {
    pub fn as_slice(&self) -> &[f32] {
        match self {
            Feats::Owned(v) => v,
            Feats::Borrowed(s) => s,
        }
    }
}

/// Per-node-set forward cache for the front-end.
pub enum FeatCache {
    Dec(DecCache),
    /// Minibatch NC: gathered rows.
    Table { x: Vec<f32> },
    /// Hash-embedding front-ends: the computed rows (both forms).
    Hash(HashCache),
    /// Full batch NC: the features *are* the table parameter — no copy.
    Full,
}

impl FeatCache {
    /// Retire the cache, returning its buffers to the step arena.
    pub fn recycle(self, scratch: &mut StepScratch) {
        match self {
            FeatCache::Dec(c) => c.recycle(scratch),
            FeatCache::Table { x } => scratch.give(x),
            FeatCache::Hash(c) => c.recycle(scratch),
            FeatCache::Full => {}
        }
    }
}

impl FeatSource {
    /// Resolve the coded front-end from manifest hyper-parameters.
    pub fn resolve_decoder(manifest: &Manifest) -> Result<FeatSource> {
        let dims = DecoderDims {
            c: manifest.hyper_usize("c")?,
            m: manifest.hyper_usize("m")?,
            d_c: manifest.hyper_usize("d_c")?,
            d_m: manifest.hyper_usize("d_m")?,
            d_e: manifest.hyper_usize("d_e")?,
            l: manifest.hyper_usize("l")?,
            light: manifest.hyper_str("variant")? == "light",
        };
        let idx = DecoderIdx::resolve(manifest, &dims)?;
        Ok(FeatSource::Decoder { dims, idx })
    }

    /// Resolve the NC front-end (`embed.table (n, d_e)`).
    pub fn resolve_table(manifest: &Manifest) -> Result<FeatSource> {
        let n = manifest.hyper_usize("n")?;
        let d = manifest.hyper_usize("d_e")?;
        let idx = find_param(manifest, "embed.table", &[n, d])?;
        Ok(FeatSource::Table { idx, n, d })
    }

    /// Resolve a hash-embedding front-end (`front_end` hyper `multihash` /
    /// `bloom` / `poshash`; dims from `hemb_k`, `hemb_b`, `hemb_bp`,
    /// `hash_seed`).
    pub fn resolve_hashemb(manifest: &Manifest, kind: &str) -> Result<FeatSource> {
        let kind = HashKind::parse(kind).ok_or_else(|| {
            Error::Config(format!("unknown hash-embedding front-end '{kind}'"))
        })?;
        let dims = HashEmbDims {
            kind,
            n: manifest.hyper_usize("n")?,
            k: manifest.hyper_usize("hemb_k")?,
            b: manifest.hyper_usize("hemb_b")?,
            bp: if kind == HashKind::Pos { manifest.hyper_usize("hemb_bp")? } else { 0 },
            d_e: manifest.hyper_usize("d_e")?,
            seed: manifest.hyper_usize("hash_seed")? as u64,
        };
        let idx = HashEmbIdx::resolve(manifest, &dims)?;
        Ok(FeatSource::HashEmb { dims, idx, pos_map: OnceLock::new() })
    }

    /// Output embedding width.
    pub fn d_out(&self) -> usize {
        match self {
            FeatSource::Decoder { dims, .. } => dims.d_e,
            FeatSource::Table { d, .. } => *d,
            FeatSource::HashEmb { dims, .. } => dims.d_e,
        }
    }

    /// Does this front-end need a bound position map before it can run?
    pub fn needs_pos_map(&self) -> bool {
        matches!(self, FeatSource::HashEmb { dims, .. } if dims.kind == HashKind::Pos)
    }

    /// Bind the poshash degree-rank bucket map (`(n,)` values `< bp`).
    /// Same contract as the full-batch adjacency bind: rebinding an equal
    /// map is a no-op, a different one is rejected, and any other
    /// front-end refuses the call.
    pub fn bind_pos_map(&self, map: Arc<Vec<u32>>) -> Result<()> {
        match self {
            FeatSource::HashEmb { dims, pos_map, .. } if dims.kind == HashKind::Pos => {
                if map.len() != dims.n {
                    return Err(Error::Shape(format!(
                        "position map has {} entries, front-end id space is {}",
                        map.len(),
                        dims.n
                    )));
                }
                if let Some(&mx) = map.iter().max() {
                    if mx as usize >= dims.bp {
                        return Err(Error::Shape(format!(
                            "position map bucket {mx} out of range [0, {})",
                            dims.bp
                        )));
                    }
                }
                if let Some(existing) = pos_map.get() {
                    if Arc::ptr_eq(existing, &map) || **existing == *map {
                        return Ok(());
                    }
                    return Err(Error::Runtime(
                        "front-end already has a different bound position map".into(),
                    ));
                }
                pos_map.set(map).map_err(|_| {
                    Error::Runtime(
                        "concurrent position-map binds raced — bind once before training"
                            .into(),
                    )
                })
            }
            _ => Err(Error::Runtime(
                "only the poshash front-end takes a position map".into(),
            )),
        }
    }

    /// The bound poshash map (`Ok(None)` for the kinds that need none).
    fn pos_map(&self) -> Result<Option<&[u32]>> {
        match self {
            FeatSource::HashEmb { dims, pos_map, .. } if dims.kind == HashKind::Pos => {
                match pos_map.get() {
                    Some(m) => Ok(Some(m.as_slice())),
                    None => Err(Error::Runtime(
                        "poshash front-end has no position map bound — call \
                         Model::bind_pos_map with the degree-rank map before \
                         train/predict"
                            .into(),
                    )),
                }
            }
            _ => Ok(None),
        }
    }

    /// Forward one node set (`t` is the codes `(rows, m)` or ids `(rows,)`
    /// tensor); returns the cache whose [`Self::output`] is `(rows, d)`.
    pub fn fwd(
        &self,
        params: &[&[f32]],
        t: &Tensor,
        threads: usize,
        scratch: &mut StepScratch,
    ) -> Result<FeatCache> {
        match self {
            FeatSource::Decoder { dims, idx } => {
                let codes = t.as_i32()?;
                let rows = codes.len() / dims.m;
                Ok(FeatCache::Dec(decoder::forward(
                    dims, idx, params, codes, rows, threads, scratch,
                )?))
            }
            FeatSource::Table { idx, n, d } => {
                let ids = t.as_i32()?;
                ops::validate_ids(ids, *n)?;
                let mut x = scratch.take(ids.len() * d);
                ops::table_gather(params[*idx], ids, *d, &mut x, threads);
                Ok(FeatCache::Table { x })
            }
            FeatSource::HashEmb { dims, idx, .. } => {
                let ids = Ids::Slice(t.as_i32()?);
                let pm = self.pos_map()?;
                Ok(FeatCache::Hash(hashemb::forward(
                    dims, idx, params, ids, pm, threads, scratch,
                )?))
            }
        }
    }

    pub fn output<'a>(&self, cache: &'a FeatCache) -> &'a [f32] {
        match cache {
            FeatCache::Dec(c) => c.output(),
            FeatCache::Table { x } => x,
            FeatCache::Hash(c) => c.output(),
            FeatCache::Full => panic!("full-graph cache has no owned output — use output_full"),
        }
    }

    /// Inference-only forward of one node set: the `(rows, d)` feature
    /// matrix with no cache behind it. Runs the same kernels as
    /// [`Self::fwd`] (decoded codes go through
    /// [`decoder::forward_infer`]), so the output is bit-identical to the
    /// training forward's [`Self::output`] at every thread count.
    pub fn infer(&self, params: &[&[f32]], t: &Tensor, threads: usize) -> Result<Vec<f32>> {
        match self {
            FeatSource::Decoder { dims, idx } => {
                let codes = t.as_i32()?;
                let rows = codes.len() / dims.m;
                decoder::forward_infer(dims, idx, params, codes, rows, threads)
            }
            FeatSource::Table { idx, n, d } => {
                let ids = t.as_i32()?;
                ops::validate_ids(ids, *n)?;
                let mut x = vec![0.0f32; ids.len() * d];
                ops::table_gather(params[*idx], ids, *d, &mut x, threads);
                Ok(x)
            }
            FeatSource::HashEmb { dims, idx, .. } => {
                let ids = Ids::Slice(t.as_i32()?);
                hashemb::forward_infer(dims, idx, params, ids, self.pos_map()?, threads)
            }
        }
    }

    /// Inference-only whole-graph forward (full-batch tasks): decoded
    /// `(n, d)` features for the coded path, the table parameter itself
    /// (borrowed, zero-copy) for NC. Mirrors [`Self::fwd_full`]'s
    /// validation; bit-identical to it.
    pub fn infer_full<'a>(
        &self,
        params: &[&'a [f32]],
        codes: Option<&Tensor>,
        n: usize,
        threads: usize,
    ) -> Result<Feats<'a>> {
        match self {
            FeatSource::Decoder { dims, idx } => {
                let t = codes.ok_or_else(|| {
                    Error::Shape("coded full-batch front-end needs a codes tensor".into())
                })?;
                let c = t.as_i32()?;
                if c.len() != n * dims.m {
                    return Err(Error::Shape(format!(
                        "full-batch codes: {} elements for n={n}, m={}",
                        c.len(),
                        dims.m
                    )));
                }
                Ok(Feats::Owned(decoder::forward_infer(dims, idx, params, c, n, threads)?))
            }
            FeatSource::Table { idx, n: nt, .. } => {
                if codes.is_some() {
                    return Err(Error::Shape("NC full-batch front-end takes no codes".into()));
                }
                if *nt != n {
                    return Err(Error::Shape(format!("embed.table has {nt} rows, graph has {n}")));
                }
                Ok(Feats::Borrowed(params[*idx]))
            }
            FeatSource::HashEmb { dims, idx, .. } => {
                if codes.is_some() {
                    return Err(Error::Shape(
                        "hash-embedding full-batch front-end takes no codes".into(),
                    ));
                }
                Ok(Feats::Owned(hashemb::forward_infer(
                    dims,
                    idx,
                    params,
                    Ids::All(n),
                    self.pos_map()?,
                    threads,
                )?))
            }
        }
    }

    /// Backward one node set: accumulate front-end parameter gradients.
    pub fn bwd(
        &self,
        params: &[&[f32]],
        t: &Tensor,
        cache: &FeatCache,
        dx: &[f32],
        trainable: &[bool],
        grads: &mut [Vec<f32>],
        threads: usize,
        scratch: &mut StepScratch,
    ) -> Result<()> {
        match (self, cache) {
            (FeatSource::Decoder { dims, idx }, FeatCache::Dec(c)) => {
                decoder::backward(
                    dims,
                    idx,
                    params,
                    t.as_i32()?,
                    c,
                    dx,
                    trainable,
                    grads,
                    threads,
                    scratch,
                );
                Ok(())
            }
            (FeatSource::Table { idx, d, .. }, FeatCache::Table { .. }) => {
                if trainable[*idx] {
                    ops::table_scatter_grad(dx, t.as_i32()?, *d, &mut grads[*idx], threads);
                }
                Ok(())
            }
            (FeatSource::HashEmb { dims, idx, .. }, FeatCache::Hash(c)) => hashemb::backward(
                dims,
                idx,
                params,
                Ids::Slice(t.as_i32()?),
                self.pos_map()?,
                c,
                dx,
                trainable,
                grads,
                threads,
            ),
            _ => Err(Error::Runtime("feature cache/source mismatch".into())),
        }
    }

    /// Forward the *whole graph*'s features (full-batch tasks): the coded
    /// path decodes an all-node `(n, m)` codes tensor; the NC path uses
    /// the table parameter directly, with no gather and no copy.
    pub fn fwd_full(
        &self,
        params: &[&[f32]],
        codes: Option<&Tensor>,
        n: usize,
        threads: usize,
        scratch: &mut StepScratch,
    ) -> Result<FeatCache> {
        match self {
            FeatSource::Decoder { dims, idx } => {
                let t = codes.ok_or_else(|| {
                    Error::Shape("coded full-batch front-end needs a codes tensor".into())
                })?;
                let c = t.as_i32()?;
                if c.len() != n * dims.m {
                    return Err(Error::Shape(format!(
                        "full-batch codes: {} elements for n={n}, m={}",
                        c.len(),
                        dims.m
                    )));
                }
                Ok(FeatCache::Dec(decoder::forward(dims, idx, params, c, n, threads, scratch)?))
            }
            FeatSource::Table { n: nt, .. } => {
                if codes.is_some() {
                    return Err(Error::Shape("NC full-batch front-end takes no codes".into()));
                }
                if *nt != n {
                    return Err(Error::Shape(format!("embed.table has {nt} rows, graph has {n}")));
                }
                Ok(FeatCache::Full)
            }
            FeatSource::HashEmb { dims, idx, .. } => {
                if codes.is_some() {
                    return Err(Error::Shape(
                        "hash-embedding full-batch front-end takes no codes".into(),
                    ));
                }
                Ok(FeatCache::Hash(hashemb::forward(
                    dims,
                    idx,
                    params,
                    Ids::All(n),
                    self.pos_map()?,
                    threads,
                    scratch,
                )?))
            }
        }
    }

    /// Feature matrix `(n, d)` of a full-graph forward.
    pub fn output_full<'a>(&self, cache: &'a FeatCache, params: &[&'a [f32]]) -> &'a [f32] {
        match (self, cache) {
            (FeatSource::Decoder { .. }, FeatCache::Dec(c)) => c.output(),
            (FeatSource::Table { idx, .. }, FeatCache::Full) => params[*idx],
            (FeatSource::HashEmb { .. }, FeatCache::Hash(c)) => c.output(),
            _ => panic!("full-graph feature cache/source mismatch"),
        }
    }

    /// Backward of [`Self::fwd_full`]: accumulate front-end parameter
    /// gradients for `dx (n, d)`.
    pub fn bwd_full(
        &self,
        params: &[&[f32]],
        codes: Option<&Tensor>,
        cache: &FeatCache,
        dx: &[f32],
        trainable: &[bool],
        grads: &mut [Vec<f32>],
        threads: usize,
        scratch: &mut StepScratch,
    ) -> Result<()> {
        match (self, cache) {
            (FeatSource::Decoder { dims, idx }, FeatCache::Dec(c)) => {
                let t = codes
                    .ok_or_else(|| Error::Shape("coded full-batch backward needs codes".into()))?;
                decoder::backward(
                    dims,
                    idx,
                    params,
                    t.as_i32()?,
                    c,
                    dx,
                    trainable,
                    grads,
                    threads,
                    scratch,
                );
                Ok(())
            }
            (FeatSource::Table { idx, .. }, FeatCache::Full) => {
                if trainable[*idx] {
                    ops::add_assign(&mut grads[*idx], dx, threads);
                }
                Ok(())
            }
            (FeatSource::HashEmb { dims, idx, .. }, FeatCache::Hash(c)) => {
                if codes.is_some() {
                    return Err(Error::Shape(
                        "hash-embedding full-batch backward takes no codes".into(),
                    ));
                }
                hashemb::backward(
                    dims,
                    idx,
                    params,
                    Ids::All(dims.n),
                    self.pos_map()?,
                    c,
                    dx,
                    trainable,
                    grads,
                    threads,
                )
            }
            _ => Err(Error::Runtime("full-graph feature cache/source mismatch".into())),
        }
    }
}

// ---------------------------------------------------------------------------
// Linear layer block
// ---------------------------------------------------------------------------

/// One resolved linear layer: parameter indices plus dims. Forward is
/// `x @ w + b` with optional fused ReLU; backward accumulates `dw`/`db`
/// and optionally back-propagates `dx = dz @ wᵀ`.
#[derive(Clone, Copy, Debug)]
pub struct LinearIdx {
    pub w: usize,
    pub b: usize,
    pub d_in: usize,
    pub d_out: usize,
}

impl LinearIdx {
    /// Resolve (and shape-check) `w (d_in, d_out)` / `b (d_out)` by name.
    pub fn resolve(
        manifest: &Manifest,
        w_name: &str,
        b_name: &str,
        d_in: usize,
        d_out: usize,
    ) -> Result<Self> {
        Ok(Self {
            w: find_param(manifest, w_name, &[d_in, d_out])?,
            b: find_param(manifest, b_name, &[d_out])?,
            d_in,
            d_out,
        })
    }

    /// `out (n, d_out) = relu?(x @ w + b)`.
    pub fn fwd(
        &self,
        params: &[&[f32]],
        x: &[f32],
        n: usize,
        relu: bool,
        out: &mut [f32],
        threads: usize,
    ) {
        ops::linear_fwd(x, params[self.w], params[self.b], n, self.d_in, self.d_out, relu, out, threads);
    }

    /// Backward for `dz (n, d_out)` — the gradient at the layer's
    /// *pre-activation* output (callers apply the ReLU mask first, as the
    /// fused forward caches only the post-activation). Accumulates
    /// `dw += xᵀ dz`, `db += Σ dz`, and writes (`accumulate_dx` ? `+=` :
    /// `=`) `dx = dz @ wᵀ` when requested.
    pub fn bwd(
        &self,
        params: &[&[f32]],
        x: &[f32],
        dz: &[f32],
        n: usize,
        trainable: &[bool],
        grads: &mut [Vec<f32>],
        dx: Option<&mut [f32]>,
        accumulate_dx: bool,
        threads: usize,
    ) {
        if trainable[self.w] {
            ops::grad_w(x, dz, n, self.d_in, self.d_out, &mut grads[self.w], threads);
        }
        if trainable[self.b] {
            ops::grad_b(dz, n, self.d_out, &mut grads[self.b]);
        }
        if let Some(dx) = dx {
            ops::matmul_wt(dz, params[self.w], n, self.d_in, self.d_out, accumulate_dx, dx, threads);
        }
    }
}

// ---------------------------------------------------------------------------
// Sparse propagation
// ---------------------------------------------------------------------------

/// Deterministic parallel SpMM `out (n_rows, d) = adj @ x` with `x
/// (n_cols, d)` row-major: threads partition output rows, each row's
/// accumulation runs in ascending stored-column order via
/// [`Csr::spmm_row_major`] — bit-identical for every thread count and to
/// the PR 1 `spmm`/`spmm_block_rows` kernels.
pub fn spmm_par(adj: &Csr, x: &[f32], d: usize, out: &mut [f32], threads: usize) {
    debug_assert_eq!(x.len(), adj.n_cols() * d);
    debug_assert_eq!(out.len(), adj.n_rows() * d);
    par_rows(out, d, threads, |row0, rows| {
        adj.spmm_row_major(row0..row0 + rows.len() / d, x, d, rows);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spmm_par_thread_invariant_and_matches_serial() {
        let a = Csr::from_edges(6, &[(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3), (0, 5)])
            .unwrap()
            .symmetrize()
            .unwrap();
        let d = 3usize;
        let x: Vec<f32> = (0..6 * d).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut base = vec![0.0f32; 6 * d];
        a.spmm_row_major(0..6, &x, d, &mut base);
        for threads in [1usize, 2, 4, 9] {
            let mut out = vec![0.0f32; 6 * d];
            spmm_par(&a, &x, d, &mut out, threads);
            assert!(
                out.iter().zip(&base).all(|(p, q)| p.to_bits() == q.to_bits()),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn linear_idx_resolves_against_manifest() {
        let m = super::super::spec::SageMbBuild {
            name: "t".into(),
            coded: false,
            link: false,
            n: 10,
            n_classes: 3,
            d_e: 4,
            hidden: 5,
            batch: 2,
            k1: 2,
            k2: 2,
            c: 4,
            m: 3,
            d_c: 4,
            d_m: 6,
            l: 2,
            light: false,
            optim: crate::cfg::OptimCfg::adamw_gnn(),
        }
        .manifest();
        let head = LinearIdx::resolve(&m, "head.w", "head.b", 5, 3).unwrap();
        assert_eq!(m.params[head.w].name, "head.w");
        assert!(LinearIdx::resolve(&m, "head.w", "head.b", 5, 4).is_err());
        assert!(LinearIdx::resolve(&m, "nope.w", "head.b", 5, 3).is_err());
    }
}
