//! Native code-dependent decoder (paper §3.2, Figure 2): gather + sum the
//! `m` per-position codebook rows selected by the integer code, optionally
//! rescale by the light variant's `W0`, then an `l`-layer MLP with ReLU
//! between linear layers. Forward caches every activation so the reverse
//! pass is a straight replay; parameter layout mirrors
//! `python/compile/decoder.py::decoder_param_specs` exactly (same names,
//! shapes, init kinds and trainable flags — validated at resolve time).

use crate::runtime::Manifest;
use crate::{Error, Result};

use super::ops;
use super::scratch::StepScratch;

/// Decoder hyper-dimensions (`c, m` coding; `d_c → d_m → … → d_e` MLP).
#[derive(Clone, Copy, Debug)]
pub struct DecoderDims {
    pub c: usize,
    pub m: usize,
    pub d_c: usize,
    pub d_m: usize,
    pub d_e: usize,
    pub l: usize,
    /// Light variant: frozen codebooks + trainable rescale `W0`.
    pub light: bool,
}

impl DecoderDims {
    /// MLP layer widths: `[d_c, d_m, …, d_m, d_e]` (length `l + 1`).
    pub fn mlp_dims(&self) -> Vec<usize> {
        let mut dims = Vec::with_capacity(self.l + 1);
        dims.push(self.d_c);
        for _ in 0..self.l - 1 {
            dims.push(self.d_m);
        }
        dims.push(self.d_e);
        dims
    }

    pub fn validate(&self) -> Result<()> {
        if self.l < 2 {
            return Err(Error::Config(format!("decoder requires l >= 2, got {}", self.l)));
        }
        for (name, v) in
            [("c", self.c), ("m", self.m), ("d_c", self.d_c), ("d_m", self.d_m), ("d_e", self.d_e)]
        {
            if v == 0 {
                return Err(Error::Config(format!("decoder {name} must be positive")));
            }
        }
        Ok(())
    }
}

/// Indices of the decoder's parameters in the manifest's canonical order.
#[derive(Clone, Debug)]
pub struct DecoderIdx {
    pub books: usize,
    pub w0: Option<usize>,
    /// `(weight, bias)` per MLP layer.
    pub mlp: Vec<(usize, usize)>,
}

/// Find a parameter by name and check its shape against the contract.
pub(super) fn find_param(manifest: &Manifest, name: &str, shape: &[usize]) -> Result<usize> {
    let i = manifest
        .params
        .iter()
        .position(|p| p.name == name)
        .ok_or_else(|| Error::Config(format!("native backend: manifest has no param '{name}'")))?;
    if manifest.params[i].shape != shape {
        return Err(Error::Shape(format!(
            "param '{name}': manifest shape {:?} != expected {:?}",
            manifest.params[i].shape, shape
        )));
    }
    Ok(i)
}

impl DecoderIdx {
    /// Resolve (and shape-check) the decoder parameters in `manifest`.
    pub fn resolve(manifest: &Manifest, dims: &DecoderDims) -> Result<Self> {
        dims.validate()?;
        let books = find_param(manifest, "dec.books", &[dims.m, dims.c, dims.d_c])?;
        let w0 = if dims.light {
            Some(find_param(manifest, "dec.w0", &[dims.d_c])?)
        } else {
            None
        };
        let mlp_dims = dims.mlp_dims();
        let mut mlp = Vec::with_capacity(dims.l);
        for i in 0..dims.l {
            let w_shape = [mlp_dims[i], mlp_dims[i + 1]];
            let w = find_param(manifest, &format!("dec.mlp{i}.w"), &w_shape)?;
            let b = find_param(manifest, &format!("dec.mlp{i}.b"), &[mlp_dims[i + 1]])?;
            mlp.push((w, b));
        }
        Ok(Self { books, w0, mlp })
    }
}

/// Forward cache: `acts[0]` is the MLP input (the rescaled gather-sum for
/// the light variant), `acts[i + 1]` the output of MLP layer `i`; the last
/// entry is the decoder output `(n, d_e)`.
pub struct DecCache {
    /// Pre-rescale gather-sum, kept only for the light variant's `dW0`.
    pub h0_raw: Option<Vec<f32>>,
    pub acts: Vec<Vec<f32>>,
}

impl DecCache {
    pub fn output(&self) -> &[f32] {
        self.acts.last().expect("decoder cache has >= 1 activation")
    }

    /// Retire the cache, returning its buffers to the arena for the next
    /// step.
    pub fn recycle(self, scratch: &mut StepScratch) {
        if let Some(h0) = self.h0_raw {
            scratch.give(h0);
        }
        scratch.give_all(self.acts);
    }
}

/// Decode `codes (n, m)` into embeddings `(n, d_e)`, caching activations.
/// Buffers come from `scratch` (bit-identical to fresh allocation).
pub fn forward(
    dims: &DecoderDims,
    idx: &DecoderIdx,
    params: &[&[f32]],
    codes: &[i32],
    n: usize,
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<DecCache> {
    ops::validate_codes(codes, dims.c)?;
    if codes.len() != n * dims.m {
        return Err(Error::Shape(format!(
            "decoder: {} code elements for {n} rows of m={}",
            codes.len(),
            dims.m
        )));
    }
    let mut h0 = scratch.take(n * dims.d_c);
    ops::codebook_fwd(params[idx.books], codes, n, dims.m, dims.c, dims.d_c, &mut h0, threads);
    let (h0_raw, first) = if let Some(w0) = idx.w0 {
        let mut scaled = scratch.take_copy(&h0);
        ops::scale_cols(&mut scaled, dims.d_c, params[w0], threads);
        (Some(h0), scaled)
    } else {
        (None, h0)
    };
    let mlp_dims = dims.mlp_dims();
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(dims.l + 1);
    acts.push(first);
    for i in 0..dims.l {
        let (w, b) = idx.mlp[i];
        let relu = i < dims.l - 1;
        let mut out = scratch.take(n * mlp_dims[i + 1]);
        ops::linear_fwd(
            &acts[i],
            params[w],
            params[b],
            n,
            mlp_dims[i],
            mlp_dims[i + 1],
            relu,
            &mut out,
            threads,
        );
        acts.push(out);
    }
    Ok(DecCache { h0_raw, acts })
}

/// Inference-only decode: bit-identical to [`forward`] for every thread
/// count, but activations are dropped as soon as the next layer has
/// consumed them — no cache, no `h0_raw`, nothing the reverse pass would
/// need. The gather-sum, the light variant's `W0` rescale, and the first
/// MLP layer run as one fused kernel ([`ops::codebook_linear_fwd`]) so
/// the `(n, d_c)` gathered matrix is never materialized; the fused kernel
/// repeats the unfused per-element operation order exactly, so fusion
/// does not change a single bit. This is the decode the serving path
/// ([`crate::serve`]) runs per request. The training [`forward`] stays
/// unfused — the reverse pass needs the intermediate activations the
/// fusion exists to skip.
pub fn forward_infer(
    dims: &DecoderDims,
    idx: &DecoderIdx,
    params: &[&[f32]],
    codes: &[i32],
    n: usize,
    threads: usize,
) -> Result<Vec<f32>> {
    ops::validate_codes(codes, dims.c)?;
    if codes.len() != n * dims.m {
        return Err(Error::Shape(format!(
            "decoder: {} code elements for {n} rows of m={}",
            codes.len(),
            dims.m
        )));
    }
    let mlp_dims = dims.mlp_dims();
    let (w1, b1) = idx.mlp[0];
    let mut cur = vec![0.0f32; n * mlp_dims[1]];
    ops::codebook_linear_fwd(
        params[idx.books],
        codes,
        n,
        dims.m,
        dims.c,
        dims.d_c,
        idx.w0.map(|w0| params[w0]),
        params[w1],
        params[b1],
        mlp_dims[1],
        dims.l > 1,
        &mut cur,
        threads,
    );
    for i in 1..dims.l {
        let (w, b) = idx.mlp[i];
        let relu = i < dims.l - 1;
        let mut out = vec![0.0f32; n * mlp_dims[i + 1]];
        ops::linear_fwd(
            &cur,
            params[w],
            params[b],
            n,
            mlp_dims[i],
            mlp_dims[i + 1],
            relu,
            &mut out,
            threads,
        );
        cur = out;
    }
    Ok(cur)
}

/// Reverse pass: accumulate parameter gradients for `d_out (n, d_e)`
/// (gradient w.r.t. the decoder output). Gradients for non-trainable
/// parameters (the light variant's frozen codebooks) are skipped — the
/// optimizer masks them anyway.
#[allow(clippy::too_many_arguments)]
pub fn backward(
    dims: &DecoderDims,
    idx: &DecoderIdx,
    params: &[&[f32]],
    codes: &[i32],
    cache: &DecCache,
    d_out: &[f32],
    trainable: &[bool],
    grads: &mut [Vec<f32>],
    threads: usize,
    scratch: &mut StepScratch,
) {
    let n = codes.len() / dims.m;
    let mlp_dims = dims.mlp_dims();
    debug_assert_eq!(d_out.len(), n * dims.d_e);
    let mut cur = scratch.take_copy(d_out);
    for i in (0..dims.l).rev() {
        let (w, b) = idx.mlp[i];
        if i < dims.l - 1 {
            ops::relu_bwd_mask(&mut cur, &cache.acts[i + 1], threads);
        }
        ops::grad_w(&cache.acts[i], &cur, n, mlp_dims[i], mlp_dims[i + 1], &mut grads[w], threads);
        ops::grad_b(&cur, n, mlp_dims[i + 1], &mut grads[b]);
        let mut prev = scratch.take(n * mlp_dims[i]);
        ops::matmul_wt(&cur, params[w], n, mlp_dims[i], mlp_dims[i + 1], false, &mut prev, threads);
        scratch.give(std::mem::replace(&mut cur, prev));
    }
    // cur = gradient w.r.t. the (possibly rescaled) gather-sum (n, d_c).
    if let Some(w0) = idx.w0 {
        let h0 = cache.h0_raw.as_ref().expect("light cache keeps h0");
        if trainable[w0] {
            let gw0 = &mut grads[w0];
            for r in 0..n {
                let hrow = &h0[r * dims.d_c..(r + 1) * dims.d_c];
                let crow = &cur[r * dims.d_c..(r + 1) * dims.d_c];
                for ((g, &h), &c) in gw0.iter_mut().zip(hrow).zip(crow) {
                    *g += h * c;
                }
            }
        }
        ops::scale_cols(&mut cur, dims.d_c, params[w0], threads);
    }
    if trainable[idx.books] {
        ops::codebook_bwd(
            &cur,
            codes,
            n,
            dims.m,
            dims.c,
            dims.d_c,
            &mut grads[idx.books],
            threads,
        );
    }
    scratch.give(cur);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamStore;
    use crate::runtime::native::spec;

    fn tiny() -> (Manifest, DecoderDims) {
        let b = spec::ReconBuild {
            name: "t".into(),
            c: 4,
            m: 3,
            d_c: 5,
            d_m: 6,
            d_e: 2,
            l: 2,
            light: false,
            batch: 4,
            optim: crate::cfg::OptimCfg::adamw_default(),
        };
        let m = b.manifest();
        let dims = DecoderDims { c: 4, m: 3, d_c: 5, d_m: 6, d_e: 2, l: 2, light: false };
        (m, dims)
    }

    #[test]
    fn resolve_checks_names_and_shapes() {
        let (m, dims) = tiny();
        let idx = DecoderIdx::resolve(&m, &dims).unwrap();
        assert_eq!(m.params[idx.books].name, "dec.books");
        assert_eq!(idx.mlp.len(), 2);
        let bad = DecoderDims { d_c: 7, ..dims };
        assert!(DecoderIdx::resolve(&m, &bad).is_err());
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let (m, dims) = tiny();
        let idx = DecoderIdx::resolve(&m, &dims).unwrap();
        let store = ParamStore::init(&m, 7);
        let params: Vec<&[f32]> = store.params.iter().map(|t| t.as_f32().unwrap()).collect();
        let codes = vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3]; // (4, 3)
        let mut sc = StepScratch::new();
        let c1 = forward(&dims, &idx, &params, &codes, 4, 1, &mut sc).unwrap();
        let c8 = forward(&dims, &idx, &params, &codes, 4, 8, &mut sc).unwrap();
        assert_eq!(c1.output().len(), 4 * 2);
        assert!(c1
            .output()
            .iter()
            .zip(c8.output())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        // Recycled-buffer forward stays bit-identical to the fresh one.
        c1.recycle(&mut sc);
        let c1b = forward(&dims, &idx, &params, &codes, 4, 1, &mut sc).unwrap();
        assert!(c1b
            .output()
            .iter()
            .zip(c8.output())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(
            forward(&dims, &idx, &params, &[0, 1, 4], 1, 1, &mut sc).is_err(),
            "code 4 out of range"
        );
    }

    #[test]
    fn forward_infer_matches_cached_forward_bitwise() {
        for light in [false, true] {
            let b = spec::ReconBuild {
                name: "t".into(),
                c: 4,
                m: 3,
                d_c: 5,
                d_m: 6,
                d_e: 2,
                l: 3,
                light,
                batch: 4,
                optim: crate::cfg::OptimCfg::adamw_default(),
            };
            let m = b.manifest();
            let dims = DecoderDims { c: 4, m: 3, d_c: 5, d_m: 6, d_e: 2, l: 3, light };
            let idx = DecoderIdx::resolve(&m, &dims).unwrap();
            let store = ParamStore::init(&m, 11);
            let params: Vec<&[f32]> = store.params.iter().map(|t| t.as_f32().unwrap()).collect();
            let codes = vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3];
            let cached =
                forward(&dims, &idx, &params, &codes, 4, 1, &mut StepScratch::new()).unwrap();
            for threads in [1usize, 8] {
                let lean = forward_infer(&dims, &idx, &params, &codes, 4, threads).unwrap();
                assert!(
                    lean.iter().zip(cached.output()).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "light={light} threads={threads}"
                );
            }
            assert!(forward_infer(&dims, &idx, &params, &[0, 1, 4], 1, 1).is_err());
        }
    }
}
