//! Hash-compressed embedding front-ends — the related-work baselines the
//! paper's §3.2 coded decoder competes against, as native
//! [`super::layers::FeatSource`] variants:
//!
//! - **multihash** (Svenstrup-style hash embeddings): K hash functions map
//!   each node id into one shared `(B, d_e)` pool; the node's embedding is
//!   the importance-weighted sum `e(v) = Σ_k imp[v,k] · pool[h_k(v)]`,
//!   with the `(n, K)` importance weights trained per node.
//! - **bloom** (bloom-filter-style bucket embeddings): the unweighted
//!   multi-probe sum with a post-aggregation nonlinearity,
//!   `e(v) = relu(Σ_k pool[h_k(v)])`.
//! - **poshash** (position-based hash embeddings): the multi-probe sum
//!   plus a *graph-structure-aware* term — nodes are ranked by degree and
//!   the rank is quantized into a small `(Bp, d_e)` position table,
//!   `e(v) = Σ_k pool[h_k(v)] + pos[pos_map[v]]`, so structurally similar
//!   nodes share a learned position row. The `(n,)` bucket map is data
//!   (derived from the training graph, see [`degree_pos_map`]), bound to
//!   the model like the full-batch adjacency and shipped in serving
//!   bundles.
//!
//! Buckets are computed on the fly from a manifest-recorded `hash_seed`
//! (one [`crate::rng::derive_stream_seed`] stream per probe, then a
//! [`mix64`] avalanche over the id) — no stored index, so training,
//! inference, and serving always agree.
//!
//! Everything follows the determinism rule of [`super::ops`]: threads
//! partition only output elements (forward: embedding rows; backward:
//! *parameter* rows, each worker scanning all batch rows in ascending
//! order exactly like [`super::ops::table_scatter_grad`]), and every
//! reduction is a fixed-order sequential sum — bit-identical for any
//! thread count.
#![allow(clippy::too_many_arguments)]

use crate::rng::{derive_stream_seed, mix64};
use crate::runtime::Manifest;
use crate::{Error, Result};

use super::decoder::find_param;
use super::ops;
use super::par::par_rows;
use super::scratch::StepScratch;

/// Which hash-embedding scheme a front-end runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashKind {
    /// Svenstrup-style: shared pool + per-node learned importance weights.
    Multi,
    /// Bloom-filter-style: multi-probe bucket sum + post-sum ReLU.
    Bloom,
    /// Kalantzi & Karypis: multi-probe sum + degree-rank position table.
    Pos,
}

impl HashKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            HashKind::Multi => "multihash",
            HashKind::Bloom => "bloom",
            HashKind::Pos => "poshash",
        }
    }

    pub fn parse(s: &str) -> Option<HashKind> {
        match s {
            "multihash" => Some(HashKind::Multi),
            "bloom" => Some(HashKind::Bloom),
            "poshash" => Some(HashKind::Pos),
            _ => None,
        }
    }
}

/// Resolved dimensions of one hash-embedding front-end.
#[derive(Clone, Copy, Debug)]
pub struct HashEmbDims {
    pub kind: HashKind,
    /// Id space (number of nodes).
    pub n: usize,
    /// Hash probes per id.
    pub k: usize,
    /// Shared pool rows (`hemb.pool (b, d_e)`).
    pub b: usize,
    /// Position-table rows (`hemb.pos (bp, d_e)`; [`HashKind::Pos`] only,
    /// 0 otherwise).
    pub bp: usize,
    pub d_e: usize,
    /// Root seed of the probe hash streams (manifest hyper `hash_seed`).
    pub seed: u64,
}

impl HashEmbDims {
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [("n", self.n), ("k", self.k), ("b", self.b), ("d_e", self.d_e)] {
            if v == 0 {
                return Err(Error::Config(format!("hashemb {name} must be positive")));
            }
        }
        if (self.kind == HashKind::Pos) != (self.bp > 0) {
            return Err(Error::Config(format!(
                "hashemb bp = {} but kind is {} — the position table exists exactly for \
                 poshash",
                self.bp,
                self.kind.as_str()
            )));
        }
        Ok(())
    }

    /// One derived seed per hash probe, hoisted out of the id loops.
    pub fn probe_seeds(&self) -> Vec<u64> {
        (0..self.k).map(|j| derive_stream_seed(self.seed, j as u64)).collect()
    }
}

/// Pool bucket of `id` under one probe's stream seed: a [`mix64`]
/// avalanche over the id (offset so id 0 still mixes), reduced mod `b`.
#[inline]
pub fn bucket(stream_seed: u64, id: usize, b: usize) -> usize {
    (mix64(stream_seed ^ (id as u64).wrapping_add(1)) % b as u64) as usize
}

/// Degree-rank position map for [`HashKind::Pos`]: nodes sorted by degree
/// descending (ties by id ascending, so the map is deterministic), rank
/// `r` of `n` quantized to bucket `r·bp/n`. High-degree nodes land in the
/// low buckets, so nodes of similar structural role share a position row.
pub fn degree_pos_map(degrees: &[usize], bp: usize) -> Vec<u32> {
    let n = degrees.len();
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(degrees[v as usize]), v));
    let mut map = vec![0u32; n];
    for (rank, &v) in order.iter().enumerate() {
        map[v as usize] = (rank * bp / n.max(1)) as u32;
    }
    map
}

/// Resolved parameter indices of one hash-embedding front-end.
#[derive(Clone, Copy, Debug)]
pub struct HashEmbIdx {
    /// `hemb.pool (b, d_e)`.
    pub pool: usize,
    /// `hemb.imp (n, k)` — [`HashKind::Multi`] only.
    pub imp: Option<usize>,
    /// `hemb.pos (bp, d_e)` — [`HashKind::Pos`] only.
    pub pos: Option<usize>,
}

impl HashEmbIdx {
    pub fn resolve(manifest: &Manifest, dims: &HashEmbDims) -> Result<Self> {
        dims.validate()?;
        let pool = find_param(manifest, "hemb.pool", &[dims.b, dims.d_e])?;
        let imp = match dims.kind {
            HashKind::Multi => Some(find_param(manifest, "hemb.imp", &[dims.n, dims.k])?),
            _ => None,
        };
        let pos = match dims.kind {
            HashKind::Pos => Some(find_param(manifest, "hemb.pos", &[dims.bp, dims.d_e])?),
            _ => None,
        };
        Ok(Self { pool, imp, pos })
    }
}

/// The node sets a front-end call covers: an explicit id tensor
/// (minibatch fan-out) or the whole graph `0..n` (full batch) — one code
/// path for both, nothing materialized for the full-graph case.
#[derive(Clone, Copy)]
pub enum Ids<'a> {
    Slice(&'a [i32]),
    /// All ids `0..n` in order.
    All(usize),
}

impl Ids<'_> {
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            Ids::Slice(s) => s.len(),
            Ids::All(n) => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    fn get(&self, r: usize) -> usize {
        match self {
            Ids::Slice(s) => s[r] as usize,
            Ids::All(_) => r,
        }
    }

    fn validate(&self, n: usize) -> Result<()> {
        match self {
            Ids::Slice(s) => ops::validate_ids(s, n),
            Ids::All(rows) => {
                if *rows != n {
                    return Err(Error::Shape(format!(
                        "hashemb full-graph forward over {rows} rows, front-end id space \
                         is {n}"
                    )));
                }
                Ok(())
            }
        }
    }
}

/// Forward cache: the front-end output `(rows, d_e)`. For bloom it doubles
/// as the ReLU mask the backward pass applies; multihash and poshash need
/// nothing but the parameters to differentiate.
pub struct HashCache {
    y: Vec<f32>,
}

impl HashCache {
    pub fn output(&self) -> &[f32] {
        &self.y
    }

    /// Retire the cache, returning its buffer to the step arena.
    pub fn recycle(self, scratch: &mut StepScratch) {
        scratch.give(self.y);
    }
}

/// Forward one node set into a cache (buffers from `scratch`, bit-identical
/// to fresh allocation).
pub fn forward(
    dims: &HashEmbDims,
    idx: &HashEmbIdx,
    params: &[&[f32]],
    ids: Ids<'_>,
    pos_map: Option<&[u32]>,
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<HashCache> {
    let mut y = scratch.take(ids.len() * dims.d_e);
    forward_into(dims, idx, params, ids, pos_map, &mut y, threads)?;
    Ok(HashCache { y })
}

/// Inference-only forward: the `(rows, d_e)` embedding matrix with no
/// cache behind it. Runs the same loops as [`forward`], so the output is
/// bit-identical to the training forward at every thread count.
pub fn forward_infer(
    dims: &HashEmbDims,
    idx: &HashEmbIdx,
    params: &[&[f32]],
    ids: Ids<'_>,
    pos_map: Option<&[u32]>,
    threads: usize,
) -> Result<Vec<f32>> {
    let mut y = vec![0.0f32; ids.len() * dims.d_e];
    forward_into(dims, idx, params, ids, pos_map, &mut y, threads)?;
    Ok(y)
}

fn forward_into(
    dims: &HashEmbDims,
    idx: &HashEmbIdx,
    params: &[&[f32]],
    ids: Ids<'_>,
    pos_map: Option<&[u32]>,
    y: &mut [f32],
    threads: usize,
) -> Result<()> {
    ids.validate(dims.n)?;
    let d = dims.d_e;
    debug_assert_eq!(y.len(), ids.len() * d);
    let seeds = dims.probe_seeds();
    let pool = params[idx.pool];
    let imp = idx.imp.map(|i| params[i]);
    let pos = idx.pos.map(|i| params[i]);
    if dims.kind == HashKind::Pos {
        let pm = pos_map.ok_or_else(|| {
            Error::Runtime("poshash forward needs the bound position map".into())
        })?;
        if pm.len() != dims.n {
            return Err(Error::Shape(format!(
                "position map has {} entries, front-end id space is {}",
                pm.len(),
                dims.n
            )));
        }
    }
    // Threads partition output rows; each row is one worker's fixed-order
    // sum over the probes (ascending j, then +pos row), so the bits never
    // depend on the thread count.
    par_rows(y, d, threads, |row0, rows| {
        for (r, orow) in rows.chunks_mut(d).enumerate() {
            let id = ids.get(row0 + r);
            for (j, &sj) in seeds.iter().enumerate() {
                let prow = &pool[bucket(sj, id, dims.b) * d..][..d];
                match imp {
                    Some(imp) => {
                        let w = imp[id * dims.k + j];
                        for (o, &p) in orow.iter_mut().zip(prow) {
                            *o += w * p;
                        }
                    }
                    None => {
                        for (o, &p) in orow.iter_mut().zip(prow) {
                            *o += p;
                        }
                    }
                }
            }
            match dims.kind {
                HashKind::Bloom => {
                    for o in orow.iter_mut() {
                        *o = o.max(0.0);
                    }
                }
                HashKind::Pos => {
                    let pm = pos_map.expect("validated above");
                    let pos = pos.expect("resolved for poshash");
                    let prow = &pos[pm[id] as usize * d..][..d];
                    for (o, &p) in orow.iter_mut().zip(prow) {
                        *o += p;
                    }
                }
                HashKind::Multi => {}
            }
        }
    });
    Ok(())
}

/// Backward one node set: accumulate front-end parameter gradients for
/// `dx (rows, d_e)`. Threads partition *parameter* rows
/// ([`super::ops::table_scatter_grad`]-style): every worker scans all
/// batch rows in ascending order and accumulates only the buckets (pool /
/// position grads) or ids (importance grads) in its range — deterministic
/// for any thread count, no scatter races. Bloom's post-sum ReLU is
/// differentiated by masking each read of `dx` with the cached output.
pub fn backward(
    dims: &HashEmbDims,
    idx: &HashEmbIdx,
    params: &[&[f32]],
    ids: Ids<'_>,
    pos_map: Option<&[u32]>,
    cache: &HashCache,
    dx: &[f32],
    trainable: &[bool],
    grads: &mut [Vec<f32>],
    threads: usize,
) -> Result<()> {
    ids.validate(dims.n)?;
    let d = dims.d_e;
    let n_rows = ids.len();
    if dx.len() != n_rows * d || cache.y.len() != n_rows * d {
        return Err(Error::Shape(format!(
            "hashemb backward: dx has {} elements, cache {}, want rows·d = {}",
            dx.len(),
            cache.y.len(),
            n_rows * d
        )));
    }
    let seeds = dims.probe_seeds();
    let bloom = dims.kind == HashKind::Bloom;
    let y = cache.y.as_slice();
    // d(relu(s))/ds masks on the cached *output*: y > 0 ⇔ pre-sum > 0.
    let dz = |r: usize, c: usize| {
        let v = dx[r * d + c];
        if bloom && y[r * d + c] <= 0.0 {
            0.0
        } else {
            v
        }
    };

    if trainable[idx.pool] {
        let imp = idx.imp.map(|i| params[i]);
        par_rows(&mut grads[idx.pool], d, threads, |row0, rows| {
            let hi = row0 + rows.len() / d;
            for r in 0..n_rows {
                let id = ids.get(r);
                for (j, &sj) in seeds.iter().enumerate() {
                    let bkt = bucket(sj, id, dims.b);
                    if bkt < row0 || bkt >= hi {
                        continue;
                    }
                    let grow = &mut rows[(bkt - row0) * d..][..d];
                    match imp {
                        Some(imp) => {
                            let w = imp[id * dims.k + j];
                            for (c, g) in grow.iter_mut().enumerate() {
                                *g += w * dz(r, c);
                            }
                        }
                        None => {
                            for (c, g) in grow.iter_mut().enumerate() {
                                *g += dz(r, c);
                            }
                        }
                    }
                }
            }
        });
    }

    if let Some(imp_idx) = idx.imp {
        if trainable[imp_idx] {
            // d imp[v,j] = ⟨dx_row, pool[h_j(v)]⟩, accumulated over every
            // batch row carrying id v (ascending r — ids repeat in a
            // batch, so this is a scatter too).
            let pool = params[idx.pool];
            let k = dims.k;
            par_rows(&mut grads[imp_idx], k, threads, |row0, rows| {
                let hi = row0 + rows.len() / k;
                for r in 0..n_rows {
                    let id = ids.get(r);
                    if id < row0 || id >= hi {
                        continue;
                    }
                    let grow = &mut rows[(id - row0) * k..][..k];
                    for (j, &sj) in seeds.iter().enumerate() {
                        let prow = &pool[bucket(sj, id, dims.b) * d..][..d];
                        let mut acc = 0.0f32;
                        for (c, &p) in prow.iter().enumerate() {
                            acc += dz(r, c) * p;
                        }
                        grow[j] += acc;
                    }
                }
            });
        }
    }

    if let Some(pos_idx) = idx.pos {
        if trainable[pos_idx] {
            let pm = pos_map.ok_or_else(|| {
                Error::Runtime("poshash backward needs the bound position map".into())
            })?;
            par_rows(&mut grads[pos_idx], d, threads, |row0, rows| {
                let hi = row0 + rows.len() / d;
                for r in 0..n_rows {
                    let bkt = pm[ids.get(r)] as usize;
                    if bkt < row0 || bkt >= hi {
                        continue;
                    }
                    let grow = &mut rows[(bkt - row0) * d..][..d];
                    for (c, g) in grow.iter_mut().enumerate() {
                        *g += dz(r, c);
                    }
                }
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_in_range_and_probe_dependent() {
        let dims = HashEmbDims {
            kind: HashKind::Bloom,
            n: 100,
            k: 4,
            b: 13,
            bp: 0,
            d_e: 3,
            seed: 9,
        };
        let seeds = dims.probe_seeds();
        assert_eq!(seeds.len(), 4);
        let mut differs = false;
        for id in 0..100 {
            let buckets: Vec<usize> = seeds.iter().map(|&s| bucket(s, id, dims.b)).collect();
            assert!(buckets.iter().all(|&b| b < 13));
            if buckets.windows(2).any(|w| w[0] != w[1]) {
                differs = true;
            }
            // Stable across calls (pure function of seed/id).
            assert_eq!(buckets, seeds.iter().map(|&s| bucket(s, id, dims.b)).collect::<Vec<_>>());
        }
        assert!(differs, "probes must not all collide on every id");
    }

    #[test]
    fn degree_pos_map_ranks_by_degree_then_id() {
        // degrees: node1 highest, nodes 0/3 tie (id ascending), node2 last.
        let map = degree_pos_map(&[5, 9, 1, 5], 4);
        assert_eq!(map, vec![1, 0, 3, 2]);
        // Quantized: 4 nodes → 2 buckets, two ranks per bucket.
        let map = degree_pos_map(&[5, 9, 1, 5], 2);
        assert_eq!(map, vec![0, 0, 1, 1]);
        assert!(degree_pos_map(&[], 3).is_empty());
    }

    #[test]
    fn bloom_forward_is_relu_of_probe_sum() {
        let dims =
            HashEmbDims { kind: HashKind::Bloom, n: 6, k: 2, b: 4, bp: 0, d_e: 2, seed: 3 };
        let pool: Vec<f32> = vec![1.0, -1.0, 0.5, -0.5, -2.0, 2.0, 0.25, -0.25];
        let idx = HashEmbIdx { pool: 0, imp: None, pos: None };
        let params: Vec<&[f32]> = vec![&pool];
        let y = forward_infer(&dims, &idx, &params, Ids::Slice(&[2, 5]), None, 1).unwrap();
        let seeds = dims.probe_seeds();
        for (r, &id) in [2usize, 5].iter().enumerate() {
            for c in 0..2 {
                let s: f32 =
                    seeds.iter().map(|&sj| pool[bucket(sj, id, 4) * 2 + c]).sum();
                assert_eq!(y[r * 2 + c], s.max(0.0), "row {r} col {c}");
            }
        }
    }

    #[test]
    fn full_graph_ids_must_match_n() {
        let dims =
            HashEmbDims { kind: HashKind::Multi, n: 5, k: 2, b: 3, bp: 0, d_e: 2, seed: 1 };
        let pool = vec![0.0f32; 6];
        let imp = vec![1.0f32; 10];
        let idx = HashEmbIdx { pool: 0, imp: Some(1), pos: None };
        let params: Vec<&[f32]> = vec![&pool, &imp];
        assert!(forward_infer(&dims, &idx, &params, Ids::All(5), None, 1).is_ok());
        assert!(forward_infer(&dims, &idx, &params, Ids::All(4), None, 1).is_err());
        assert!(forward_infer(&dims, &idx, &params, Ids::Slice(&[5]), None, 1).is_err());
    }
}
