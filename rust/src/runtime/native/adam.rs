//! Fused AdamW (Loshchilov & Hutter 2018) for the native backend —
//! bit-for-bit the update `python/compile/optim.py` lowers into every
//! train-step executable: biased moments, bias correction with
//! `t = completed_steps + 1`, decoupled weight decay. Purely elementwise,
//! so parallel chunking is trivially deterministic.

use crate::ser::Json;
use crate::Result;

/// Optimizer hyper-parameters (burned into the manifest's `hyper.optim`).
#[derive(Clone, Copy, Debug)]
pub struct AdamHyper {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl AdamHyper {
    /// Parse from a manifest's `hyper.optim` object.
    pub fn from_json(optim: &Json) -> Result<Self> {
        Ok(Self {
            lr: optim.get("lr")?.as_f64()? as f32,
            beta1: optim.get("beta1")?.as_f64()? as f32,
            beta2: optim.get("beta2")?.as_f64()? as f32,
            eps: optim.get("eps")?.as_f64()? as f32,
            weight_decay: optim.get("weight_decay")?.as_f64()? as f32,
        })
    }
}

#[allow(clippy::needless_range_loop, clippy::too_many_arguments)]
fn update_chunk(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    bc1: f32,
    bc2: f32,
    h: AdamHyper,
) {
    for i in 0..p.len() {
        let m_new = h.beta1 * m[i] + (1.0 - h.beta1) * g[i];
        let v_new = h.beta2 * v[i] + (1.0 - h.beta2) * g[i] * g[i];
        m[i] = m_new;
        v[i] = v_new;
        let mhat = m_new / bc1;
        let vhat = v_new / bc2;
        let update = mhat / (vhat.sqrt() + h.eps) + h.weight_decay * p[i];
        p[i] -= h.lr * update;
    }
}

/// One AdamW step over a single parameter tensor, in place. `t` is the
/// *completed*-step counter plus one (matching the f32 `step` input the
/// executables receive).
pub fn adamw_update(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    t: f32,
    h: AdamHyper,
    threads: usize,
) {
    debug_assert_eq!(p.len(), g.len());
    debug_assert_eq!(p.len(), m.len());
    debug_assert_eq!(p.len(), v.len());
    let bc1 = 1.0 - h.beta1.powf(t);
    let bc2 = 1.0 - h.beta2.powf(t);
    let len = p.len();
    if len == 0 {
        return;
    }
    let workers = threads.clamp(1, len);
    if workers == 1 {
        update_chunk(p, g, m, v, bc1, bc2, h);
        return;
    }
    let chunk = len.div_ceil(workers);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = p
        .chunks_mut(chunk)
        .zip(m.chunks_mut(chunk))
        .zip(v.chunks_mut(chunk))
        .zip(g.chunks(chunk))
        .map(|(((pc, mc), vc), gc)| {
            Box::new(move || update_chunk(pc, gc, mc, vc, bc1, bc2, h))
                as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    super::par::join_all(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper() -> AdamHyper {
        AdamHyper { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.01 }
    }

    #[test]
    fn first_step_matches_reference_math() {
        // Fresh moments, t=1: m=(1-b1)g, v=(1-b2)g²; mhat=g, vhat=g².
        let g = vec![0.5f32, -2.0];
        let mut p = vec![1.0f32, 1.0];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        let h = hyper();
        adamw_update(&mut p, &g, &mut m, &mut v, 1.0, h, 1);
        for (i, &gi) in g.iter().enumerate() {
            let mhat = gi; // (1-b1)g / (1-b1)
            let vhat = gi * gi;
            let expect = 1.0 - h.lr * (mhat / (vhat.sqrt() + h.eps) + h.weight_decay * 1.0);
            assert!((p[i] - expect).abs() < 1e-6, "{} vs {}", p[i], expect);
        }
        assert!((m[0] - 0.05).abs() < 1e-7);
        assert!((v[1] - 0.004).abs() < 1e-6);
    }

    #[test]
    fn thread_count_invariant() {
        let g: Vec<f32> = (0..1000).map(|i| ((i * 37 % 101) as f32 - 50.0) / 25.0).collect();
        let mut run = |threads: usize| {
            let mut p: Vec<f32> = (0..1000).map(|i| (i as f32) / 500.0 - 1.0).collect();
            let mut m = vec![0.1f32; 1000];
            let mut v = vec![0.2f32; 1000];
            for t in 1..5 {
                adamw_update(&mut p, &g, &mut m, &mut v, t as f32, hyper(), threads);
            }
            (p, m, v)
        };
        let a = run(1);
        let b = run(7);
        assert!(a.0.iter().zip(&b.0).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(a.1.iter().zip(&b.1).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(a.2.iter().zip(&b.2).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn parses_manifest_optim_object() {
        let j = crate::ser::parse(
            r#"{"lr": 0.01, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.0}"#,
        )
        .unwrap();
        let h = AdamHyper::from_json(&j).unwrap();
        assert!((h.lr - 0.01).abs() < 1e-9);
        assert_eq!(h.weight_decay, 0.0);
    }
}
