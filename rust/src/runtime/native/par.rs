//! Deterministic parallel substrate for the native backend: one reusable
//! worker pool instead of OS-thread spawns on every kernel call.
//!
//! PR 2 spawned `std::thread::scope` threads inside every kernel; a train
//! step makes dozens of kernel calls, so thread creation dominated small
//! problems. The pool here is spawned once per process (lazily, sized to
//! `available_parallelism() - 1` detached workers parked on channels) and
//! every kernel dispatches borrowed closures to it via [`join_all`].
//! Dispatch is lock-free — each call carries its own completion channel,
//! so concurrent callers (e.g. parallel tests, multiple models) share the
//! workers instead of serializing behind a dispatch mutex.
//!
//! The determinism rule every kernel in [`super::ops`] follows is
//! unchanged: **threads only ever partition output elements** — each
//! output element is produced by exactly one job as a sequential reduction
//! in a fixed order over the reduction axis — and the partition depends
//! only on the *requested* `threads` value, never on pool size or
//! scheduling, so results are bit-identical for every thread count and on
//! every machine.
//!
//! ## Safety model
//!
//! Jobs borrow the caller's stack (`&mut` output chunks, `&` inputs), so
//! their lifetimes are erased before crossing the channel. This is sound
//! because [`join_all`] does not return — and does not unwind — until
//! every dispatched job has sent its completion on the call-local channel:
//! the borrows outlive every use. A drop guard drains outstanding
//! completions even if the locally run job panics, and worker panics are
//! caught, forwarded, and re-raised on the calling thread after the
//! barrier.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::OnceLock;

/// Resolve a thread-count knob (`0` = all available parallelism).
pub(crate) fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }
}

/// One unit of work as it crosses a worker channel: the lifetime-erased
/// closure plus the dispatching call's completion sender.
struct Job {
    run: Box<dyn FnOnce() + Send + 'static>,
    done: Sender<std::thread::Result<()>>,
}

struct Pool {
    /// One channel per detached worker thread. `mpsc::Sender` is `Sync`
    /// (T: Send), so dispatch needs no lock.
    workers: Vec<Sender<Job>>,
    /// Round-robin start offset so concurrent dispatchers spread across
    /// workers instead of all queueing on worker 0. Purely a scheduling
    /// hint — never affects results (jobs own disjoint outputs).
    next: AtomicUsize,
}

thread_local! {
    /// Set on pool workers so nested [`join_all`] calls run inline instead
    /// of deadlocking on their own queue.
    static IN_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .saturating_sub(1);
        let mut workers = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, rx) = channel::<Job>();
            std::thread::Builder::new()
                .name(format!("hashgnn-pool-{w}"))
                .spawn(move || {
                    IN_POOL_WORKER.with(|f| f.set(true));
                    while let Ok(job) = rx.recv() {
                        let result = catch_unwind(AssertUnwindSafe(job.run));
                        // A dropped receiver just means the dispatcher is
                        // unwinding its drain guard; nothing to do.
                        let _ = job.done.send(result);
                    }
                })
                .expect("spawn hashgnn pool worker");
            workers.push(tx);
        }
        Pool { workers, next: AtomicUsize::new(0) }
    })
}

/// Waits for outstanding pool jobs even while unwinding, so borrows the
/// jobs captured can never dangle.
struct Drain<'a> {
    rx: &'a Receiver<std::thread::Result<()>>,
    outstanding: usize,
}

impl Drop for Drain<'_> {
    fn drop(&mut self) {
        while self.outstanding > 0 {
            match self.rx.recv() {
                Ok(_) => self.outstanding -= 1,
                // All job-held senders dropped: every remaining job already
                // finished (send happens strictly after the closure runs).
                Err(_) => break,
            }
        }
    }
}

/// Run a batch of borrowed closures: job 0 on the calling thread, the rest
/// on the pool (round-robin from a rotating start, queued in order per
/// worker). Blocks until all jobs finish; panics from any job are
/// re-raised here afterwards. Called from a pool worker (nested
/// parallelism) or with an empty pool, jobs run inline in order — same
/// results either way, since jobs own disjoint outputs.
pub(crate) fn join_all<'env>(jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
    let n = jobs.len();
    if n == 0 {
        return;
    }
    if n == 1 || IN_POOL_WORKER.with(|f| f.get()) {
        for job in jobs {
            job();
        }
        return;
    }
    let pool = pool();
    let n_workers = pool.workers.len();
    if n_workers == 0 {
        for job in jobs {
            job();
        }
        return;
    }
    let (done_tx, done_rx) = channel();
    let start = pool.next.fetch_add(n - 1, Ordering::Relaxed);
    let mut it = jobs.into_iter();
    let local = it.next().expect("checked non-empty");
    let mut drain = Drain { rx: &done_rx, outstanding: 0 };
    for (k, job) in it.enumerate() {
        // SAFETY: the job's completion is collected below (by the loop, or
        // by `Drain::drop` on any unwind path) before this frame — and
        // therefore every borrow the job captures — is left, so erasing
        // the lifetime cannot let the job outlive its data.
        let run: Box<dyn FnOnce() + Send + 'static> = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'env>,
                Box<dyn FnOnce() + Send + 'static>,
            >(job)
        };
        let job = Job { run, done: done_tx.clone() };
        let w = start.wrapping_add(k) % n_workers;
        pool.workers[w].send(job).expect("pool worker channel closed");
        drain.outstanding += 1;
    }
    // Keep no spare sender: once every dispatched job has sent (or been
    // dropped with its worker), recv() can only yield what we wait for.
    drop(done_tx);
    let local_result = catch_unwind(AssertUnwindSafe(local));
    let mut worker_panic: Option<Box<dyn std::any::Any + Send>> = None;
    while drain.outstanding > 0 {
        match drain.rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(p)) => {
                if worker_panic.is_none() {
                    worker_panic = Some(p);
                }
            }
            Err(_) => panic!("worker pool completion channel closed"),
        }
        drain.outstanding -= 1;
    }
    drop(drain);
    if let Err(p) = local_result {
        resume_unwind(p);
    }
    if let Some(p) = worker_panic {
        resume_unwind(p);
    }
}

/// Split `out` into contiguous row chunks (rows of `stride` elements) and
/// run `f(first_row_index, chunk)` per chunk on the worker pool. `threads`
/// is the resolved worker count; the chunking depends only on it, so
/// output bits never depend on pool size or scheduling.
pub(crate) fn par_rows(
    out: &mut [f32],
    stride: usize,
    threads: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    debug_assert!(stride > 0, "par_rows stride must be positive");
    debug_assert_eq!(out.len() % stride, 0, "par_rows: length not a multiple of stride");
    let n_rows = out.len() / stride;
    if n_rows == 0 {
        return;
    }
    let t = threads.clamp(1, n_rows);
    if t == 1 {
        f(0, out);
        return;
    }
    let chunk = n_rows.div_ceil(t);
    let f = &f;
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(chunk * stride)
        .enumerate()
        .map(|(i, part)| Box::new(move || f(i * chunk, part)) as Box<dyn FnOnce() + Send + '_>)
        .collect();
    join_all(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_threads_sentinel() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn par_rows_covers_all_rows_once() {
        for threads in [1usize, 2, 3, 8, 100] {
            let mut out = vec![0.0f32; 7 * 3];
            par_rows(&mut out, 3, threads, |row0, rows| {
                for (i, r) in rows.chunks_mut(3).enumerate() {
                    for v in r.iter_mut() {
                        *v += (row0 + i) as f32 + 1.0;
                    }
                }
            });
            let expect: Vec<f32> =
                (0..7).flat_map(|r| [r as f32 + 1.0; 3]).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn par_rows_empty_is_noop() {
        let mut out: Vec<f32> = Vec::new();
        par_rows(&mut out, 4, 8, |_r, _c| panic!("must not be called"));
    }

    #[test]
    fn join_all_runs_every_job_and_pool_is_reusable() {
        // Many rounds on the same process-wide pool: no spawn-per-call, no
        // cross-talk between dispatches (each owns its completion channel).
        for round in 0..50usize {
            let mut cells = vec![0usize; 9];
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = cells
                .iter_mut()
                .enumerate()
                .map(|(i, c)| Box::new(move || *c = i + round) as Box<dyn FnOnce() + Send + '_>)
                .collect();
            join_all(jobs);
            for (i, &c) in cells.iter().enumerate() {
                assert_eq!(c, i + round);
            }
        }
    }

    #[test]
    fn concurrent_dispatchers_share_the_pool() {
        // Several threads dispatching simultaneously: every dispatch sees
        // exactly its own completions (per-call channels, no lock).
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    for round in 0..20usize {
                        let mut out = vec![0.0f32; 12];
                        par_rows(&mut out, 1, 4, |row0, part| {
                            for (i, v) in part.iter_mut().enumerate() {
                                *v = (t * 1000 + round + row0 + i) as f32;
                            }
                        });
                        for (i, &v) in out.iter().enumerate() {
                            assert_eq!(v, (t * 1000 + round + i) as f32);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn panics_propagate_and_pool_survives() {
        let caught = std::panic::catch_unwind(|| {
            let mut out = vec![0.0f32; 8];
            par_rows(&mut out, 1, 4, |row0, _c| {
                if row0 >= 4 {
                    panic!("boom in worker");
                }
            });
        });
        assert!(caught.is_err(), "worker panic must reach the caller");
        // The pool must still work afterwards.
        let mut out = vec![0.0f32; 6];
        par_rows(&mut out, 1, 3, |row0, part| {
            for (i, v) in part.iter_mut().enumerate() {
                *v = (row0 + i) as f32;
            }
        });
        assert_eq!(out, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }
}
