//! Deterministic scoped-thread helpers for the native backend.
//!
//! Same zero-dependency style as the LSH encode engine: workers get
//! disjoint `&mut` row views via `chunks_mut`, spawned with
//! `std::thread::scope`. The determinism rule every kernel in
//! [`super::ops`] follows: **threads only ever partition output
//! elements** — each output element is produced by exactly one worker as
//! a sequential reduction in a fixed order over the reduction axis — so
//! results are bit-identical for every thread count.

/// Resolve a thread-count knob (`0` = all available parallelism).
pub(crate) fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }
}

/// Split `out` into contiguous row chunks (rows of `stride` elements) and
/// run `f(first_row_index, chunk)` per chunk, on scoped threads when more
/// than one chunk is produced. `threads` is the resolved worker count.
pub(crate) fn par_rows(
    out: &mut [f32],
    stride: usize,
    threads: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    debug_assert!(stride > 0, "par_rows stride must be positive");
    debug_assert_eq!(out.len() % stride, 0, "par_rows: length not a multiple of stride");
    let n_rows = out.len() / stride;
    if n_rows == 0 {
        return;
    }
    let t = threads.clamp(1, n_rows);
    if t == 1 {
        f(0, out);
        return;
    }
    let chunk = n_rows.div_ceil(t);
    std::thread::scope(|s| {
        let f = &f;
        for (i, part) in out.chunks_mut(chunk * stride).enumerate() {
            s.spawn(move || f(i * chunk, part));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_threads_sentinel() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn par_rows_covers_all_rows_once() {
        for threads in [1usize, 2, 3, 8, 100] {
            let mut out = vec![0.0f32; 7 * 3];
            par_rows(&mut out, 3, threads, |row0, rows| {
                for (i, r) in rows.chunks_mut(3).enumerate() {
                    for v in r.iter_mut() {
                        *v += (row0 + i) as f32 + 1.0;
                    }
                }
            });
            let expect: Vec<f32> =
                (0..7).flat_map(|r| [r as f32 + 1.0; 3]).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn par_rows_empty_is_noop() {
        let mut out: Vec<f32> = Vec::new();
        par_rows(&mut out, 4, 8, |_r, _c| panic!("must not be called"));
    }
}
