//! Rust-side model builds: synthesize a [`Manifest`] without any AOT
//! artifact on disk. Parameter lists mirror `python/compile/decoder.py` /
//! `gnn.py` name-for-name and init-for-init, and the hyper object carries
//! the same keys `python/compile/aot.py` records — so a natively
//! synthesized manifest and an exported one are interchangeable, and
//! [`crate::params::ParamStore::init`] produces identical buffers for
//! both.
//!
//! [`builtin`] is the native analog of the aot.py variant registry: the
//! artifact names the CLI and tasks reference (`sage_mb_coded`,
//! `sage_mb_nc`, `merchant`, `recon_c16_m32`, …) resolve to the same
//! scales the Python exporter uses, plus the native-only `sage_mb_link`
//! (the §4 dot-product/BPR link head, which has no HLO counterpart).

use crate::cfg::{GnnKind, OptimCfg};
use crate::runtime::{InitKind, Manifest, ParamSpec, TensorSpec};
use crate::ser::Json;

use super::decoder::DecoderDims;
use super::hashemb::HashKind;

fn param(name: String, shape: Vec<usize>, init: InitKind, trainable: bool) -> ParamSpec {
    ParamSpec { name, shape, init, trainable }
}

fn xavier(name: &str, shape: Vec<usize>) -> ParamSpec {
    param(name.to_string(), shape, InitKind::XavierUniform, true)
}

fn zeros(name: &str, shape: Vec<usize>) -> ParamSpec {
    param(name.to_string(), shape, InitKind::Zeros, true)
}

fn tensor(name: &str, shape: Vec<usize>, dtype: &str) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape, dtype: dtype.to_string() }
}

/// Decoder parameter list (mirrors `decoder.decoder_param_specs`).
pub fn decoder_param_specs(
    c: usize,
    m: usize,
    d_c: usize,
    d_m: usize,
    d_e: usize,
    l: usize,
    light: bool,
) -> Vec<ParamSpec> {
    let mut specs = vec![param(
        "dec.books".to_string(),
        vec![m, c, d_c],
        InitKind::Normal { std: 1.0 / (m as f32).sqrt() },
        !light,
    )];
    if light {
        specs.push(param("dec.w0".to_string(), vec![d_c], InitKind::Ones, true));
    }
    // One source of truth for the MLP layout: the resolver's dims.
    let dims = DecoderDims { c, m, d_c, d_m, d_e, l, light }.mlp_dims();
    for i in 0..l {
        specs.push(xavier(&format!("dec.mlp{i}.w"), vec![dims[i], dims[i + 1]]));
        specs.push(zeros(&format!("dec.mlp{i}.b"), vec![dims[i + 1]]));
    }
    specs
}

/// Minibatch-SAGE parameter list (mirrors `gnn.sage_mb_param_specs`).
pub fn sage_mb_param_specs(d_in: usize, hidden: usize) -> Vec<ParamSpec> {
    vec![
        xavier("gnn.w1", vec![2 * d_in, hidden]),
        zeros("gnn.b1", vec![hidden]),
        xavier("gnn.w2", vec![2 * hidden, hidden]),
        zeros("gnn.b2", vec![hidden]),
    ]
}

/// Full-batch GCN parameter list (mirrors `gnn.gcn_param_specs`):
/// 2 layers of self-loop propagation + linear skip connection.
pub fn gcn_param_specs(d_in: usize, hidden: usize) -> Vec<ParamSpec> {
    vec![
        xavier("gnn.w1", vec![d_in, hidden]),
        xavier("gnn.s1", vec![d_in, hidden]),
        zeros("gnn.b1", vec![hidden]),
        xavier("gnn.w2", vec![hidden, hidden]),
        xavier("gnn.s2", vec![hidden, hidden]),
        zeros("gnn.b2", vec![hidden]),
    ]
}

/// Full-batch SGC parameter list (mirrors `gnn.sgc_param_specs`): one
/// linear map of `Â²x`.
pub fn sgc_param_specs(d_in: usize, hidden: usize) -> Vec<ParamSpec> {
    vec![xavier("gnn.w", vec![d_in, hidden]), zeros("gnn.b", vec![hidden])]
}

/// Full-batch GIN parameter list (mirrors `gnn.gin_param_specs`): 2 GIN
/// layers, each a trainable ε plus a 2-layer MLP.
pub fn gin_param_specs(d_in: usize, hidden: usize) -> Vec<ParamSpec> {
    vec![
        zeros("gnn.eps1", vec![1]),
        xavier("gnn.m1a.w", vec![d_in, hidden]),
        zeros("gnn.m1a.b", vec![hidden]),
        xavier("gnn.m1b.w", vec![hidden, hidden]),
        zeros("gnn.m1b.b", vec![hidden]),
        zeros("gnn.eps2", vec![1]),
        xavier("gnn.m2a.w", vec![hidden, hidden]),
        zeros("gnn.m2a.b", vec![hidden]),
        xavier("gnn.m2b.w", vec![hidden, hidden]),
        zeros("gnn.m2b.b", vec![hidden]),
    ]
}

/// Full-batch GraphSAGE parameter list (mirrors `gnn.sage_fb_param_specs`
/// — same layout as the minibatch encoder).
pub fn sage_fb_param_specs(d_in: usize, hidden: usize) -> Vec<ParamSpec> {
    sage_mb_param_specs(d_in, hidden)
}

/// Specs plus the adjacency normalization each §5.2 architecture expects
/// (mirrors `gnn.FULLBATCH`).
fn fullbatch_gnn_specs(gnn: GnnKind, d_e: usize, hidden: usize) -> (Vec<ParamSpec>, &'static str) {
    match gnn {
        GnnKind::Gcn => (gcn_param_specs(d_e, hidden), "sym_norm"),
        GnnKind::Sgc => (sgc_param_specs(d_e, hidden), "sym_norm"),
        GnnKind::Gin => (gin_param_specs(d_e, hidden), "raw"),
        GnnKind::Sage => (sage_fb_param_specs(d_e, hidden), "row_norm"),
    }
}

/// Classification-head parameter list (mirrors `gnn.head_param_specs`).
pub fn head_param_specs(hidden: usize, n_out: usize) -> Vec<ParamSpec> {
    vec![xavier("head.w", vec![hidden, n_out]), zeros("head.b", vec![n_out])]
}

/// NC baseline's explicit embedding table.
pub fn embed_table_spec(n: usize, d_e: usize) -> ParamSpec {
    param("embed.table".to_string(), vec![n, d_e], InitKind::Normal { std: 0.1 }, true)
}

// ---------------------------------------------------------------------------
// Hash-embedding front-ends (multihash / bloom / poshash)
// ---------------------------------------------------------------------------

/// f32 element count of the §3.2 decoder front-end's parameters at these
/// dims — one term of the coded byte budget the hash front-ends are sized
/// against.
pub fn decoder_frontend_f32s(
    c: usize,
    m: usize,
    d_c: usize,
    d_m: usize,
    d_e: usize,
    l: usize,
    light: bool,
) -> usize {
    decoder_param_specs(c, m, d_c, d_m, d_e, l, light)
        .iter()
        .map(|p| p.shape.iter().product::<usize>())
        .sum()
}

/// Total bytes of the coded front-end for an `n`-node graph: 4 bytes per
/// parameter f32 plus the packed `(n, m)` code words at `⌈log₂ c⌉` bits
/// per code — the bytes-fair budget every hash front-end is sized to
/// match.
pub fn coded_frontend_bytes(
    n: usize,
    c: usize,
    m: usize,
    d_c: usize,
    d_m: usize,
    d_e: usize,
    l: usize,
    light: bool,
) -> usize {
    let code_bits = (usize::BITS - (c.max(2) - 1).leading_zeros()) as usize;
    4 * decoder_frontend_f32s(c, m, d_c, d_m, d_e, l, light) + (n * m * code_bits).div_ceil(8)
}

/// Pool rows giving a hash front-end the target byte budget after
/// `fixed_f32s` non-pool parameters are paid for:
/// `4·(rows·d_e + fixed_f32s) ≈ budget_bytes`, at least 1.
pub fn hemb_rows_for_budget(budget_bytes: usize, d_e: usize, fixed_f32s: usize) -> usize {
    ((budget_bytes / 4).saturating_sub(fixed_f32s) / d_e).max(1)
}

/// One hash-embedding front-end configuration (see
/// [`super::hashemb`]): kind, probe count, pool rows, position-table rows
/// (poshash only) and the hash-stream seed. Plugs into the SAGE and
/// full-batch builds via [`SageMbBuild::manifest_hash`] /
/// [`FullBatchBuild::manifest_hash`].
#[derive(Clone, Copy, Debug)]
pub struct HashFrontEnd {
    pub kind: HashKind,
    pub k: usize,
    pub b: usize,
    /// Position-table rows; must be 0 unless `kind` is poshash.
    pub bp: usize,
    pub seed: u64,
}

impl HashFrontEnd {
    /// Bytes-fair configuration: pool rows solved so the front-end's total
    /// parameter bytes match `budget_bytes` (normally
    /// [`coded_frontend_bytes`] at the same scales). Multihash pays the
    /// `(n, k)` importance weights out of the budget first; poshash
    /// reserves an `n/8`-row position table (capped at 256 rows).
    pub fn budget_matched(
        kind: HashKind,
        n: usize,
        d_e: usize,
        k: usize,
        seed: u64,
        budget_bytes: usize,
    ) -> HashFrontEnd {
        let (bp, fixed) = match kind {
            HashKind::Multi => (0, n * k),
            HashKind::Bloom => (0, 0),
            HashKind::Pos => {
                let bp = (n / 8).clamp(1, 256);
                (bp, bp * d_e)
            }
        };
        let b = hemb_rows_for_budget(budget_bytes, d_e, fixed);
        HashFrontEnd { kind, k, b, bp, seed }
    }

    /// Front-end parameter list (replaces `embed.table` in the NC builds).
    /// The importance weights start at 1 so multihash begins as the plain
    /// probe sum; both tables init like the NC table.
    pub fn param_specs(&self, n: usize, d_e: usize) -> Vec<ParamSpec> {
        let mut specs = vec![param(
            "hemb.pool".to_string(),
            vec![self.b, d_e],
            InitKind::Normal { std: 0.1 },
            true,
        )];
        if self.kind == HashKind::Multi {
            specs.push(param("hemb.imp".to_string(), vec![n, self.k], InitKind::Ones, true));
        }
        if self.kind == HashKind::Pos {
            specs.push(param(
                "hemb.pos".to_string(),
                vec![self.bp, d_e],
                InitKind::Normal { std: 0.1 },
                true,
            ));
        }
        specs
    }

    /// f32 element count of [`Self::param_specs`].
    pub fn f32s(&self, n: usize, d_e: usize) -> usize {
        self.param_specs(n, d_e).iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }

    /// Rewrite an NC-shaped manifest in place: swap `embed.table` (always
    /// params[0]) for this front-end's parameters and record the
    /// `front_end` / `hemb_*` / `hash_seed` hyper keys the resolver reads.
    fn apply(&self, m: &mut Manifest, n: usize, d_e: usize) {
        debug_assert_eq!(m.params[0].name, "embed.table");
        let mut params = self.param_specs(n, d_e);
        params.extend(m.params.split_off(1));
        m.params = params;
        if let Json::Obj(o) = &mut m.hyper {
            o.insert("front_end".to_string(), Json::str(self.kind.as_str()));
            o.insert("hemb_k".to_string(), Json::num(self.k as f64));
            o.insert("hemb_b".to_string(), Json::num(self.b as f64));
            o.insert("hemb_bp".to_string(), Json::num(self.bp as f64));
            o.insert("hash_seed".to_string(), Json::num(self.seed as f64));
        }
    }
}

/// One §5.1 reconstruction-decoder build.
#[derive(Clone, Debug)]
pub struct ReconBuild {
    pub name: String,
    pub c: usize,
    pub m: usize,
    pub d_c: usize,
    pub d_m: usize,
    pub d_e: usize,
    pub l: usize,
    pub light: bool,
    pub batch: usize,
    pub optim: OptimCfg,
}

impl ReconBuild {
    pub fn manifest(&self) -> Manifest {
        let hyper = Json::obj(vec![
            ("task", Json::str("recon")),
            ("c", Json::num(self.c as f64)),
            ("m", Json::num(self.m as f64)),
            ("d_c", Json::num(self.d_c as f64)),
            ("d_m", Json::num(self.d_m as f64)),
            ("d_e", Json::num(self.d_e as f64)),
            ("l", Json::num(self.l as f64)),
            ("variant", Json::str(if self.light { "light" } else { "full" })),
            ("batch", Json::num(self.batch as f64)),
            ("optim", self.optim.to_json()),
        ]);
        let params =
            decoder_param_specs(self.c, self.m, self.d_c, self.d_m, self.d_e, self.l, self.light);
        Manifest {
            name: self.name.clone(),
            params,
            train_inputs: vec![
                tensor("codes", vec![self.batch, self.m], "i32"),
                tensor("target", vec![self.batch, self.d_e], "f32"),
            ],
            pred_inputs: vec![tensor("codes", vec![self.batch, self.m], "i32")],
            pred_output: tensor("embedding", vec![self.batch, self.d_e], "f32"),
            hyper,
        }
    }
}

/// One §4 minibatch-GraphSAGE build (node classification or link head).
#[derive(Clone, Debug)]
pub struct SageMbBuild {
    pub name: String,
    pub coded: bool,
    /// Dot-product/BPR link head instead of the softmax-CE node head.
    pub link: bool,
    pub n: usize,
    pub n_classes: usize,
    pub d_e: usize,
    pub hidden: usize,
    pub batch: usize,
    pub k1: usize,
    pub k2: usize,
    pub c: usize,
    pub m: usize,
    pub d_c: usize,
    pub d_m: usize,
    pub l: usize,
    pub light: bool,
    pub optim: OptimCfg,
}

impl SageMbBuild {
    /// The three node-set input tensors for one encoder application.
    /// The clf head uses the exact aot.py names (`codes_b`, `codes_h1`,
    /// `codes_h2`); the link head's three node sets get `u`/`v`/`w`
    /// prefixes (`codes_u`, `codes_u_h1`, …).
    fn node_inputs(&self, prefix: &str) -> Vec<TensorSpec> {
        let (b, k1, k2, m) = (self.batch, self.k1, self.k2, self.m);
        let kind = if self.coded { "codes" } else { "ids" };
        let names = if prefix == "b" {
            [format!("{kind}_b"), format!("{kind}_h1"), format!("{kind}_h2")]
        } else {
            [
                format!("{kind}_{prefix}"),
                format!("{kind}_{prefix}_h1"),
                format!("{kind}_{prefix}_h2"),
            ]
        };
        let shapes: [Vec<usize>; 3] = if self.coded {
            [vec![b, m], vec![b * k1, m], vec![b * k1 * k2, m]]
        } else {
            [vec![b], vec![b * k1], vec![b * k1 * k2]]
        };
        names
            .into_iter()
            .zip(shapes)
            .map(|(name, shape)| tensor(&name, shape, "i32"))
            .collect()
    }

    pub fn manifest(&self) -> Manifest {
        let task = if self.link { "sage_minibatch_link" } else { "sage_minibatch" };
        let hyper = Json::obj(vec![
            ("task", Json::str(task)),
            ("coded", Json::Bool(self.coded)),
            ("n", Json::num(self.n as f64)),
            ("n_classes", Json::num(self.n_classes as f64)),
            ("d_e", Json::num(self.d_e as f64)),
            ("hidden", Json::num(self.hidden as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("k1", Json::num(self.k1 as f64)),
            ("k2", Json::num(self.k2 as f64)),
            ("c", Json::num(self.c as f64)),
            ("m", Json::num(self.m as f64)),
            ("d_c", Json::num(self.d_c as f64)),
            ("d_m", Json::num(self.d_m as f64)),
            ("l", Json::num(self.l as f64)),
            ("variant", Json::str(if self.light { "light" } else { "full" })),
            ("optim", self.optim.to_json()),
        ]);
        let mut params = if self.coded {
            decoder_param_specs(self.c, self.m, self.d_c, self.d_m, self.d_e, self.l, self.light)
        } else {
            vec![embed_table_spec(self.n, self.d_e)]
        };
        params.extend(sage_mb_param_specs(self.d_e, self.hidden));
        let (train_inputs, pred_inputs, pred_output) = if self.link {
            let mut train = self.node_inputs("u");
            train.extend(self.node_inputs("v"));
            train.extend(self.node_inputs("w"));
            let mut pred = self.node_inputs("u");
            pred.extend(self.node_inputs("v"));
            (train, pred, tensor("scores", vec![self.batch], "f32"))
        } else {
            params.extend(head_param_specs(self.hidden, self.n_classes));
            let mut train = self.node_inputs("b");
            train.push(tensor("labels", vec![self.batch], "i32"));
            let pred = self.node_inputs("b");
            (train, pred, tensor("logits", vec![self.batch, self.n_classes], "f32"))
        };
        Manifest { name: self.name.clone(), params, train_inputs, pred_inputs, pred_output, hyper }
    }

    /// Manifest with a hash-embedding front-end in place of the NC table.
    /// Requires `coded = false` (the input tensors are node ids, exactly
    /// the NC shapes); the front-end params replace `embed.table` and the
    /// `front_end`/`hemb_*`/`hash_seed` hyper keys are recorded.
    pub fn manifest_hash(&self, fe: &HashFrontEnd) -> Manifest {
        assert!(!self.coded, "hash front-ends build on the NC (ids-input) shape");
        let mut m = self.manifest();
        fe.apply(&mut m, self.n, self.d_e);
        m
    }

    /// The §3.2 coded front-end's byte budget at this build's scales —
    /// what [`HashFrontEnd::budget_matched`] sizes against.
    pub fn coded_budget_bytes(&self) -> usize {
        coded_frontend_bytes(
            self.n, self.c, self.m, self.d_c, self.d_m, self.d_e, self.l, self.light,
        )
    }
}

/// One §5.2 full-batch build (Table-1 cell): GCN / SGC / GIN / SAGE over
/// the whole graph, node classification or link prediction, coded or NC.
///
/// The synthesized manifest carries the same hyper keys
/// `model.make_nodeclf_fullbatch` / `make_linkpred_fullbatch` record, but
/// **no `adj` input tensor**: the native backend takes the adjacency as a
/// sparse CSR bound via [`crate::runtime::Model::bind_adjacency`], so no
/// dense `n×n` buffer ever exists on this path. (Exported HLO manifests
/// that do declare `adj` have it stripped at native load.)
#[derive(Clone, Debug)]
pub struct FullBatchBuild {
    pub name: String,
    pub gnn: GnnKind,
    pub coded: bool,
    /// Dot-product/BCE link scorer instead of the masked-CE node head.
    pub link: bool,
    pub n: usize,
    pub n_classes: usize,
    pub d_e: usize,
    pub hidden: usize,
    pub c: usize,
    pub m: usize,
    pub d_c: usize,
    pub d_m: usize,
    pub l: usize,
    pub light: bool,
    pub e_train: usize,
    pub e_pred: usize,
    pub optim: OptimCfg,
}

impl FullBatchBuild {
    pub fn manifest(&self) -> Manifest {
        let (gnn_specs, adj_kind) = fullbatch_gnn_specs(self.gnn, self.d_e, self.hidden);
        let task = if self.link { "linkpred_fullbatch" } else { "nodeclf_fullbatch" };
        let mut hyper = vec![
            ("task", Json::str(task)),
            ("gnn", Json::str(self.gnn.as_str())),
            ("adj", Json::str(adj_kind)),
            ("coded", Json::Bool(self.coded)),
            ("n", Json::num(self.n as f64)),
            ("d_e", Json::num(self.d_e as f64)),
            ("hidden", Json::num(self.hidden as f64)),
            ("c", Json::num(self.c as f64)),
            ("m", Json::num(self.m as f64)),
            ("d_c", Json::num(self.d_c as f64)),
            ("d_m", Json::num(self.d_m as f64)),
            ("l", Json::num(self.l as f64)),
            ("variant", Json::str(if self.light { "light" } else { "full" })),
            ("optim", self.optim.to_json()),
        ];
        if self.link {
            hyper.push(("e_train", Json::num(self.e_train as f64)));
            hyper.push(("e_pred", Json::num(self.e_pred as f64)));
        } else {
            hyper.push(("n_classes", Json::num(self.n_classes as f64)));
        }
        let mut params = if self.coded {
            decoder_param_specs(self.c, self.m, self.d_c, self.d_m, self.d_e, self.l, self.light)
        } else {
            vec![embed_table_spec(self.n, self.d_e)]
        };
        params.extend(gnn_specs);
        let code_in: Vec<TensorSpec> = if self.coded {
            vec![tensor("codes", vec![self.n, self.m], "i32")]
        } else {
            Vec::new()
        };
        let (train_inputs, pred_inputs, pred_output) = if self.link {
            let mut train = code_in.clone();
            train.push(tensor("pos_edges", vec![self.e_train, 2], "i32"));
            train.push(tensor("neg_edges", vec![self.e_train, 2], "i32"));
            let mut pred = code_in;
            pred.push(tensor("edges", vec![self.e_pred, 2], "i32"));
            (train, pred, tensor("scores", vec![self.e_pred], "f32"))
        } else {
            params.extend(head_param_specs(self.hidden, self.n_classes));
            let mut train = code_in.clone();
            train.push(tensor("labels", vec![self.n], "i32"));
            train.push(tensor("mask", vec![self.n], "f32"));
            (train, code_in, tensor("logits", vec![self.n, self.n_classes], "f32"))
        };
        Manifest {
            name: self.name.clone(),
            params,
            train_inputs,
            pred_inputs,
            pred_output,
            hyper: Json::obj(hyper),
        }
    }

    /// Manifest with a hash-embedding front-end in place of the NC table
    /// (requires `coded = false`; full-batch hash models take no input
    /// tensors for the front-end — ids are implicitly `0..n`).
    pub fn manifest_hash(&self, fe: &HashFrontEnd) -> Manifest {
        assert!(!self.coded, "hash front-ends build on the NC (no-codes) shape");
        let mut m = self.manifest();
        fe.apply(&mut m, self.n, self.d_e);
        m
    }

    /// The §3.2 coded front-end's byte budget at this build's scales —
    /// what [`HashFrontEnd::budget_matched`] sizes against.
    pub fn coded_budget_bytes(&self) -> usize {
        coded_frontend_bytes(
            self.n, self.c, self.m, self.d_c, self.d_m, self.d_e, self.l, self.light,
        )
    }
}

// ---------------------------------------------------------------------------
// Built-in registry (scales mirror python/compile/aot.py)
// ---------------------------------------------------------------------------

fn mb_build(name: &str, coded: bool, link: bool) -> SageMbBuild {
    SageMbBuild {
        name: name.to_string(),
        coded,
        link,
        n: 10_000,
        n_classes: 8,
        d_e: 64,
        hidden: 128,
        batch: 256,
        k1: 10,
        k2: 10,
        c: 16,
        m: 32,
        d_c: 128,
        d_m: 128,
        l: 3,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    }
}

fn merchant_build() -> SageMbBuild {
    SageMbBuild {
        name: "merchant".to_string(),
        coded: true,
        link: false,
        n: 60_000,
        n_classes: 64,
        d_e: 64,
        hidden: 128,
        batch: 256,
        k1: 5,
        k2: 5,
        c: 256,
        m: 16,
        d_c: 128,
        d_m: 128,
        l: 3,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    }
}

/// Table-1 scale (mirrors aot.py `T1`): n nodes per synthetic OGB analog,
/// shared across datasets so one build set serves all of them.
fn fb_build(gnn: GnnKind, coded: bool, link: bool) -> FullBatchBuild {
    let prefix = if link { "link_fb" } else { "node_fb" };
    let tag = if coded { "coded" } else { "nc" };
    FullBatchBuild {
        name: format!("{prefix}_{}_{tag}", gnn.as_str()),
        gnn,
        coded,
        link,
        n: 1024,
        n_classes: 8,
        d_e: 64,
        hidden: 64,
        c: 16,
        m: 32,
        d_c: 128,
        d_m: 128,
        l: 3,
        light: false,
        e_train: 512,
        e_pred: 4096,
        optim: OptimCfg::adamw_gnn(),
    }
}

/// Default hash-front-end knobs for registry builds: 2 probes per id
/// (the Svenstrup setting) and a fixed hash-stream seed, both overridable
/// by custom builds via [`HashFrontEnd`] directly.
pub const HASH_FE_K: usize = 2;
pub const HASH_FE_SEED: u64 = 17;

/// Registry-default hash front-end for an `n`-node build: bytes-fair vs
/// the coded front-end at the build's own scales.
fn registry_hash_fe(kind: HashKind, n: usize, d_e: usize, budget: usize) -> HashFrontEnd {
    HashFrontEnd::budget_matched(kind, n, d_e, HASH_FE_K, HASH_FE_SEED, budget)
}

/// Parse a `node_fb_{gnn}_{tag}` / `link_fb_{gnn}_{tag}` name, where
/// `tag` is `coded`, `nc`, or a hash front-end kind (`multihash` /
/// `bloom` / `poshash`).
fn parse_fb_name(name: &str) -> Option<Manifest> {
    let (link, rest) = if let Some(r) = name.strip_prefix("node_fb_") {
        (false, r)
    } else if let Some(r) = name.strip_prefix("link_fb_") {
        (true, r)
    } else {
        return None;
    };
    let (gnn_s, tag) = rest.rsplit_once('_')?;
    let gnn = GnnKind::parse(gnn_s).ok()?;
    match tag {
        "coded" => Some(fb_build(gnn, true, link).manifest()),
        "nc" => Some(fb_build(gnn, false, link).manifest()),
        _ => {
            let kind = HashKind::parse(tag)?;
            let mut b = fb_build(gnn, false, link);
            b.name = name.to_string();
            let fe = registry_hash_fe(kind, b.n, b.d_e, b.coded_budget_bytes());
            Some(b.manifest_hash(&fe))
        }
    }
}

fn recon_build(name: &str, c: usize, m: usize, light: bool) -> ReconBuild {
    ReconBuild {
        name: name.to_string(),
        c,
        m,
        d_c: 256,
        d_m: 256,
        d_e: 128,
        l: 3,
        light,
        batch: 512,
        optim: OptimCfg::adamw_default(),
    }
}

/// Names the native registry can synthesize without artifacts.
pub fn builtin_names() -> &'static [&'static str] {
    &[
        "sage_mb_coded",
        "sage_mb_nc",
        "sage_mb_link",
        // Hash-embedding front-ends, bytes-fair vs sage_mb_coded.
        "sage_mb_multihash",
        "sage_mb_bloom",
        "sage_mb_poshash",
        "merchant",
        "recon_c2_m128",
        "recon_c4_m64",
        "recon_c16_m32",
        "recon_c256_m16",
        "recon_light_c16_m32",
        // §5.2 Table-1 full-batch grid: 4 GNNs × {node, link} × {coded, nc}.
        "node_fb_gcn_coded",
        "node_fb_gcn_nc",
        "node_fb_sgc_coded",
        "node_fb_sgc_nc",
        "node_fb_gin_coded",
        "node_fb_gin_nc",
        "node_fb_sage_coded",
        "node_fb_sage_nc",
        // Hash front-ends run the same grid (any gnn × {node, link});
        // the GIN rows are the listed representatives.
        "node_fb_gin_multihash",
        "node_fb_gin_bloom",
        "node_fb_gin_poshash",
        "link_fb_gcn_coded",
        "link_fb_gcn_nc",
        "link_fb_sgc_coded",
        "link_fb_sgc_nc",
        "link_fb_gin_coded",
        "link_fb_gin_nc",
        "link_fb_sage_coded",
        "link_fb_sage_nc",
    ]
}

/// Synthesize the manifest for a registry name (`None` if unknown).
pub fn builtin(name: &str) -> Option<Manifest> {
    if let Some(m) = parse_fb_name(name) {
        return Some(m);
    }
    if let Some(tag) = name.strip_prefix("sage_mb_") {
        if let Some(kind) = HashKind::parse(tag) {
            let b = mb_build(name, false, false);
            let fe = registry_hash_fe(kind, b.n, b.d_e, b.coded_budget_bytes());
            return Some(b.manifest_hash(&fe));
        }
    }
    match name {
        "sage_mb_coded" => Some(mb_build(name, true, false).manifest()),
        "sage_mb_nc" => Some(mb_build(name, false, false).manifest()),
        "sage_mb_link" => Some(mb_build(name, true, true).manifest()),
        "merchant" => Some(merchant_build().manifest()),
        "recon_c2_m128" => Some(recon_build(name, 2, 128, false).manifest()),
        "recon_c4_m64" => Some(recon_build(name, 4, 64, false).manifest()),
        "recon_c16_m32" => Some(recon_build(name, 16, 32, false).manifest()),
        "recon_c256_m16" => Some(recon_build(name, 256, 16, false).manifest()),
        "recon_light_c16_m32" => Some(recon_build(name, 16, 32, true).manifest()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamStore;

    #[test]
    fn sage_coded_manifest_matches_aot_contract() {
        let m = builtin("sage_mb_coded").unwrap();
        assert_eq!(m.name, "sage_mb_coded");
        // Param order: decoder, gnn, head (same as model.make_sage_minibatch).
        let names: Vec<&str> = m.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "dec.books", "dec.mlp0.w", "dec.mlp0.b", "dec.mlp1.w", "dec.mlp1.b",
                "dec.mlp2.w", "dec.mlp2.b", "gnn.w1", "gnn.b1", "gnn.w2", "gnn.b2",
                "head.w", "head.b"
            ]
        );
        assert_eq!(m.params[0].shape, vec![32, 16, 128]);
        assert!(m.params[0].trainable, "full variant trains codebooks");
        assert_eq!(m.train_inputs.len(), 4);
        assert_eq!(m.train_inputs[2].shape, vec![256 * 10 * 10, 32]);
        assert_eq!(m.pred_output.shape, vec![256, 8]);
        assert_eq!(m.hyper_usize("k1").unwrap(), 10);
        assert_eq!(m.hyper_str("task").unwrap(), "sage_minibatch");
        // Stores initialize from synthesized manifests like exported ones.
        let store = ParamStore::init(&m, 3);
        assert_eq!(store.n_params(), 13);
    }

    #[test]
    fn nc_and_link_and_recon_variants() {
        let nc = builtin("sage_mb_nc").unwrap();
        assert_eq!(nc.params[0].name, "embed.table");
        assert_eq!(nc.params[0].shape, vec![10_000, 64]);
        assert_eq!(nc.train_inputs[0].shape, vec![256]);

        let link = builtin("sage_mb_link").unwrap();
        assert_eq!(link.train_inputs.len(), 9);
        assert_eq!(link.pred_inputs.len(), 6);
        assert_eq!(link.pred_output.shape, vec![256]);
        assert!(!link.params.iter().any(|p| p.name.starts_with("head.")));

        let recon = builtin("recon_c16_m32").unwrap();
        assert_eq!(recon.params.len(), 7);
        assert_eq!(recon.hyper_usize("batch").unwrap(), 512);

        let light = builtin("recon_light_c16_m32").unwrap();
        assert!(!light.params[0].trainable, "light variant freezes codebooks");
        assert_eq!(light.params[1].name, "dec.w0");

        for name in builtin_names() {
            assert!(builtin(name).is_some(), "{name} must synthesize");
        }
        assert!(builtin("node_fb_gat_coded").is_none(), "unknown gnn kinds stay unknown");
        assert!(builtin("node_fb_gcn").is_none(), "tag is required");
    }

    #[test]
    fn hash_front_end_manifests_are_bytes_fair() {
        let budget = coded_frontend_bytes(10_000, 16, 32, 128, 128, 64, 3, false);
        for (name, extra) in [
            ("sage_mb_multihash", Some("hemb.imp")),
            ("sage_mb_bloom", None),
            ("sage_mb_poshash", Some("hemb.pos")),
        ] {
            let m = builtin(name).unwrap();
            let tag = name.strip_prefix("sage_mb_").unwrap();
            assert_eq!(m.hyper_str("front_end").unwrap(), tag);
            assert!(!m.hyper_bool("coded").unwrap(), "{name} must not claim codes");
            assert_eq!(m.hyper_usize("hemb_k").unwrap(), HASH_FE_K);
            assert_eq!(m.hyper_usize("hash_seed").unwrap() as u64, HASH_FE_SEED);
            assert_eq!(m.params[0].name, "hemb.pool");
            match extra {
                Some(p) => assert_eq!(m.params[1].name, p, "{name}"),
                None => assert!(m.params[1].name.starts_with("gnn."), "{name}"),
            }
            // Input tensors are the NC id shapes, not code matrices.
            assert_eq!(m.train_inputs[0].shape, vec![256]);
            // Bytes-fair: front-end parameter bytes fill the coded budget
            // to within one pool row.
            let fe_bytes: usize = 4 * m
                .params
                .iter()
                .filter(|p| p.name.starts_with("hemb."))
                .map(|p| p.shape.iter().product::<usize>())
                .sum::<usize>();
            assert!(fe_bytes <= budget, "{name}: {fe_bytes} > {budget}");
            assert!(fe_bytes + 4 * 65 > budget, "{name}: {fe_bytes} undershoots {budget}");
            // The resolver accepts it (registry → native model contract).
            assert!(super::super::NativeModel::from_manifest(&m).is_ok(), "{name}");
        }
        // The full-batch grid takes the same tags for every gnn × head.
        for name in ["node_fb_gin_multihash", "node_fb_sage_bloom", "link_fb_gcn_poshash"] {
            let m = builtin(name).unwrap();
            assert_eq!(m.name, name);
            assert!(m.params.iter().any(|p| p.name == "hemb.pool"), "{name}");
            assert!(
                !m.train_inputs.iter().any(|t| t.name == "codes"),
                "{name} must not take a codes tensor"
            );
        }
        assert!(builtin("node_fb_gin_nope").is_none());
        for name in builtin_names() {
            assert!(builtin(name).is_some(), "{name} must synthesize");
        }
    }

    #[test]
    fn fullbatch_manifests_match_model_py_contract() {
        // GIN node-clf, coded: decoder + gin + head params in model.py order.
        let m = builtin("node_fb_gin_coded").unwrap();
        assert_eq!(m.hyper_str("task").unwrap(), "nodeclf_fullbatch");
        assert_eq!(m.hyper_str("gnn").unwrap(), "gin");
        assert_eq!(m.hyper_str("adj").unwrap(), "raw");
        assert!(m.hyper_bool("coded").unwrap());
        let names: Vec<&str> = m.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "dec.books", "dec.mlp0.w", "dec.mlp0.b", "dec.mlp1.w", "dec.mlp1.b",
                "dec.mlp2.w", "dec.mlp2.b", "gnn.eps1", "gnn.m1a.w", "gnn.m1a.b",
                "gnn.m1b.w", "gnn.m1b.b", "gnn.eps2", "gnn.m2a.w", "gnn.m2a.b",
                "gnn.m2b.w", "gnn.m2b.b", "head.w", "head.b"
            ]
        );
        // Native manifests never declare a dense adj input.
        let train_names: Vec<&str> = m.train_inputs.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(train_names, vec!["codes", "labels", "mask"]);
        assert_eq!(m.train_inputs[0].shape, vec![1024, 32]);
        assert_eq!(m.pred_output.shape, vec![1024, 8]);
        assert_eq!(m.pred_inputs.len(), 1);

        // GCN has the skip-connection params.
        let gcn = builtin("node_fb_gcn_nc").unwrap();
        assert_eq!(gcn.hyper_str("adj").unwrap(), "sym_norm");
        assert_eq!(gcn.params[0].name, "embed.table");
        assert_eq!(gcn.params[0].shape, vec![1024, 64]);
        assert!(gcn.params.iter().any(|p| p.name == "gnn.s1"));
        assert!(gcn.pred_inputs.is_empty(), "nc pred needs no batch tensors");

        // Link builds: edge tensors, no head, e_pred-shaped scores.
        let link = builtin("link_fb_sage_nc").unwrap();
        assert_eq!(link.hyper_str("task").unwrap(), "linkpred_fullbatch");
        assert_eq!(link.hyper_str("adj").unwrap(), "row_norm");
        assert_eq!(link.hyper_usize("e_train").unwrap(), 512);
        let train_names: Vec<&str> = link.train_inputs.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(train_names, vec!["pos_edges", "neg_edges"]);
        assert_eq!(link.train_inputs[0].shape, vec![512, 2]);
        assert_eq!(link.pred_output.shape, vec![4096]);
        assert!(!link.params.iter().any(|p| p.name.starts_with("head.")));

        // SGC is two params + head.
        let sgc = builtin("node_fb_sgc_coded").unwrap();
        assert!(sgc.params.iter().any(|p| p.name == "gnn.w"));
        assert_eq!(sgc.params.iter().filter(|p| p.name.starts_with("gnn.")).count(), 2);
    }
}
