//! Rust-side model builds: synthesize a [`Manifest`] without any AOT
//! artifact on disk. Parameter lists mirror `python/compile/decoder.py` /
//! `gnn.py` name-for-name and init-for-init, and the hyper object carries
//! the same keys `python/compile/aot.py` records — so a natively
//! synthesized manifest and an exported one are interchangeable, and
//! [`crate::params::ParamStore::init`] produces identical buffers for
//! both.
//!
//! [`builtin`] is the native analog of the aot.py variant registry: the
//! artifact names the CLI and tasks reference (`sage_mb_coded`,
//! `sage_mb_nc`, `merchant`, `recon_c16_m32`, …) resolve to the same
//! scales the Python exporter uses, plus the native-only `sage_mb_link`
//! (the §4 dot-product/BPR link head, which has no HLO counterpart).

use crate::cfg::OptimCfg;
use crate::runtime::{InitKind, Manifest, ParamSpec, TensorSpec};
use crate::ser::Json;

use super::decoder::DecoderDims;

fn param(name: String, shape: Vec<usize>, init: InitKind, trainable: bool) -> ParamSpec {
    ParamSpec { name, shape, init, trainable }
}

fn xavier(name: &str, shape: Vec<usize>) -> ParamSpec {
    param(name.to_string(), shape, InitKind::XavierUniform, true)
}

fn zeros(name: &str, shape: Vec<usize>) -> ParamSpec {
    param(name.to_string(), shape, InitKind::Zeros, true)
}

fn tensor(name: &str, shape: Vec<usize>, dtype: &str) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape, dtype: dtype.to_string() }
}

/// Decoder parameter list (mirrors `decoder.decoder_param_specs`).
pub fn decoder_param_specs(
    c: usize,
    m: usize,
    d_c: usize,
    d_m: usize,
    d_e: usize,
    l: usize,
    light: bool,
) -> Vec<ParamSpec> {
    let mut specs = vec![param(
        "dec.books".to_string(),
        vec![m, c, d_c],
        InitKind::Normal { std: 1.0 / (m as f32).sqrt() },
        !light,
    )];
    if light {
        specs.push(param("dec.w0".to_string(), vec![d_c], InitKind::Ones, true));
    }
    // One source of truth for the MLP layout: the resolver's dims.
    let dims = DecoderDims { c, m, d_c, d_m, d_e, l, light }.mlp_dims();
    for i in 0..l {
        specs.push(xavier(&format!("dec.mlp{i}.w"), vec![dims[i], dims[i + 1]]));
        specs.push(zeros(&format!("dec.mlp{i}.b"), vec![dims[i + 1]]));
    }
    specs
}

/// Minibatch-SAGE parameter list (mirrors `gnn.sage_mb_param_specs`).
pub fn sage_mb_param_specs(d_in: usize, hidden: usize) -> Vec<ParamSpec> {
    vec![
        xavier("gnn.w1", vec![2 * d_in, hidden]),
        zeros("gnn.b1", vec![hidden]),
        xavier("gnn.w2", vec![2 * hidden, hidden]),
        zeros("gnn.b2", vec![hidden]),
    ]
}

/// Classification-head parameter list (mirrors `gnn.head_param_specs`).
pub fn head_param_specs(hidden: usize, n_out: usize) -> Vec<ParamSpec> {
    vec![xavier("head.w", vec![hidden, n_out]), zeros("head.b", vec![n_out])]
}

/// NC baseline's explicit embedding table.
pub fn embed_table_spec(n: usize, d_e: usize) -> ParamSpec {
    param("embed.table".to_string(), vec![n, d_e], InitKind::Normal { std: 0.1 }, true)
}

/// One §5.1 reconstruction-decoder build.
#[derive(Clone, Debug)]
pub struct ReconBuild {
    pub name: String,
    pub c: usize,
    pub m: usize,
    pub d_c: usize,
    pub d_m: usize,
    pub d_e: usize,
    pub l: usize,
    pub light: bool,
    pub batch: usize,
    pub optim: OptimCfg,
}

impl ReconBuild {
    pub fn manifest(&self) -> Manifest {
        let hyper = Json::obj(vec![
            ("task", Json::str("recon")),
            ("c", Json::num(self.c as f64)),
            ("m", Json::num(self.m as f64)),
            ("d_c", Json::num(self.d_c as f64)),
            ("d_m", Json::num(self.d_m as f64)),
            ("d_e", Json::num(self.d_e as f64)),
            ("l", Json::num(self.l as f64)),
            ("variant", Json::str(if self.light { "light" } else { "full" })),
            ("batch", Json::num(self.batch as f64)),
            ("optim", self.optim.to_json()),
        ]);
        let params =
            decoder_param_specs(self.c, self.m, self.d_c, self.d_m, self.d_e, self.l, self.light);
        Manifest {
            name: self.name.clone(),
            params,
            train_inputs: vec![
                tensor("codes", vec![self.batch, self.m], "i32"),
                tensor("target", vec![self.batch, self.d_e], "f32"),
            ],
            pred_inputs: vec![tensor("codes", vec![self.batch, self.m], "i32")],
            pred_output: tensor("embedding", vec![self.batch, self.d_e], "f32"),
            hyper,
        }
    }
}

/// One §4 minibatch-GraphSAGE build (node classification or link head).
#[derive(Clone, Debug)]
pub struct SageMbBuild {
    pub name: String,
    pub coded: bool,
    /// Dot-product/BPR link head instead of the softmax-CE node head.
    pub link: bool,
    pub n: usize,
    pub n_classes: usize,
    pub d_e: usize,
    pub hidden: usize,
    pub batch: usize,
    pub k1: usize,
    pub k2: usize,
    pub c: usize,
    pub m: usize,
    pub d_c: usize,
    pub d_m: usize,
    pub l: usize,
    pub light: bool,
    pub optim: OptimCfg,
}

impl SageMbBuild {
    /// The three node-set input tensors for one encoder application.
    /// The clf head uses the exact aot.py names (`codes_b`, `codes_h1`,
    /// `codes_h2`); the link head's three node sets get `u`/`v`/`w`
    /// prefixes (`codes_u`, `codes_u_h1`, …).
    fn node_inputs(&self, prefix: &str) -> Vec<TensorSpec> {
        let (b, k1, k2, m) = (self.batch, self.k1, self.k2, self.m);
        let kind = if self.coded { "codes" } else { "ids" };
        let names = if prefix == "b" {
            [format!("{kind}_b"), format!("{kind}_h1"), format!("{kind}_h2")]
        } else {
            [
                format!("{kind}_{prefix}"),
                format!("{kind}_{prefix}_h1"),
                format!("{kind}_{prefix}_h2"),
            ]
        };
        let shapes: [Vec<usize>; 3] = if self.coded {
            [vec![b, m], vec![b * k1, m], vec![b * k1 * k2, m]]
        } else {
            [vec![b], vec![b * k1], vec![b * k1 * k2]]
        };
        names
            .into_iter()
            .zip(shapes)
            .map(|(name, shape)| tensor(&name, shape, "i32"))
            .collect()
    }

    pub fn manifest(&self) -> Manifest {
        let task = if self.link { "sage_minibatch_link" } else { "sage_minibatch" };
        let hyper = Json::obj(vec![
            ("task", Json::str(task)),
            ("coded", Json::Bool(self.coded)),
            ("n", Json::num(self.n as f64)),
            ("n_classes", Json::num(self.n_classes as f64)),
            ("d_e", Json::num(self.d_e as f64)),
            ("hidden", Json::num(self.hidden as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("k1", Json::num(self.k1 as f64)),
            ("k2", Json::num(self.k2 as f64)),
            ("c", Json::num(self.c as f64)),
            ("m", Json::num(self.m as f64)),
            ("d_c", Json::num(self.d_c as f64)),
            ("d_m", Json::num(self.d_m as f64)),
            ("l", Json::num(self.l as f64)),
            ("variant", Json::str(if self.light { "light" } else { "full" })),
            ("optim", self.optim.to_json()),
        ]);
        let mut params = if self.coded {
            decoder_param_specs(self.c, self.m, self.d_c, self.d_m, self.d_e, self.l, self.light)
        } else {
            vec![embed_table_spec(self.n, self.d_e)]
        };
        params.extend(sage_mb_param_specs(self.d_e, self.hidden));
        let (train_inputs, pred_inputs, pred_output) = if self.link {
            let mut train = self.node_inputs("u");
            train.extend(self.node_inputs("v"));
            train.extend(self.node_inputs("w"));
            let mut pred = self.node_inputs("u");
            pred.extend(self.node_inputs("v"));
            (train, pred, tensor("scores", vec![self.batch], "f32"))
        } else {
            params.extend(head_param_specs(self.hidden, self.n_classes));
            let mut train = self.node_inputs("b");
            train.push(tensor("labels", vec![self.batch], "i32"));
            let pred = self.node_inputs("b");
            (train, pred, tensor("logits", vec![self.batch, self.n_classes], "f32"))
        };
        Manifest { name: self.name.clone(), params, train_inputs, pred_inputs, pred_output, hyper }
    }
}

// ---------------------------------------------------------------------------
// Built-in registry (scales mirror python/compile/aot.py)
// ---------------------------------------------------------------------------

fn mb_build(name: &str, coded: bool, link: bool) -> SageMbBuild {
    SageMbBuild {
        name: name.to_string(),
        coded,
        link,
        n: 10_000,
        n_classes: 8,
        d_e: 64,
        hidden: 128,
        batch: 256,
        k1: 10,
        k2: 10,
        c: 16,
        m: 32,
        d_c: 128,
        d_m: 128,
        l: 3,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    }
}

fn merchant_build() -> SageMbBuild {
    SageMbBuild {
        name: "merchant".to_string(),
        coded: true,
        link: false,
        n: 60_000,
        n_classes: 64,
        d_e: 64,
        hidden: 128,
        batch: 256,
        k1: 5,
        k2: 5,
        c: 256,
        m: 16,
        d_c: 128,
        d_m: 128,
        l: 3,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    }
}

fn recon_build(name: &str, c: usize, m: usize, light: bool) -> ReconBuild {
    ReconBuild {
        name: name.to_string(),
        c,
        m,
        d_c: 256,
        d_m: 256,
        d_e: 128,
        l: 3,
        light,
        batch: 512,
        optim: OptimCfg::adamw_default(),
    }
}

/// Names the native registry can synthesize without artifacts.
pub fn builtin_names() -> &'static [&'static str] {
    &[
        "sage_mb_coded",
        "sage_mb_nc",
        "sage_mb_link",
        "merchant",
        "recon_c2_m128",
        "recon_c4_m64",
        "recon_c16_m32",
        "recon_c256_m16",
        "recon_light_c16_m32",
    ]
}

/// Synthesize the manifest for a registry name (`None` if unknown).
pub fn builtin(name: &str) -> Option<Manifest> {
    match name {
        "sage_mb_coded" => Some(mb_build(name, true, false).manifest()),
        "sage_mb_nc" => Some(mb_build(name, false, false).manifest()),
        "sage_mb_link" => Some(mb_build(name, true, true).manifest()),
        "merchant" => Some(merchant_build().manifest()),
        "recon_c2_m128" => Some(recon_build(name, 2, 128, false).manifest()),
        "recon_c4_m64" => Some(recon_build(name, 4, 64, false).manifest()),
        "recon_c16_m32" => Some(recon_build(name, 16, 32, false).manifest()),
        "recon_c256_m16" => Some(recon_build(name, 256, 16, false).manifest()),
        "recon_light_c16_m32" => Some(recon_build(name, 16, 32, true).manifest()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamStore;

    #[test]
    fn sage_coded_manifest_matches_aot_contract() {
        let m = builtin("sage_mb_coded").unwrap();
        assert_eq!(m.name, "sage_mb_coded");
        // Param order: decoder, gnn, head (same as model.make_sage_minibatch).
        let names: Vec<&str> = m.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "dec.books", "dec.mlp0.w", "dec.mlp0.b", "dec.mlp1.w", "dec.mlp1.b",
                "dec.mlp2.w", "dec.mlp2.b", "gnn.w1", "gnn.b1", "gnn.w2", "gnn.b2",
                "head.w", "head.b"
            ]
        );
        assert_eq!(m.params[0].shape, vec![32, 16, 128]);
        assert!(m.params[0].trainable, "full variant trains codebooks");
        assert_eq!(m.train_inputs.len(), 4);
        assert_eq!(m.train_inputs[2].shape, vec![256 * 10 * 10, 32]);
        assert_eq!(m.pred_output.shape, vec![256, 8]);
        assert_eq!(m.hyper_usize("k1").unwrap(), 10);
        assert_eq!(m.hyper_str("task").unwrap(), "sage_minibatch");
        // Stores initialize from synthesized manifests like exported ones.
        let store = ParamStore::init(&m, 3);
        assert_eq!(store.n_params(), 13);
    }

    #[test]
    fn nc_and_link_and_recon_variants() {
        let nc = builtin("sage_mb_nc").unwrap();
        assert_eq!(nc.params[0].name, "embed.table");
        assert_eq!(nc.params[0].shape, vec![10_000, 64]);
        assert_eq!(nc.train_inputs[0].shape, vec![256]);

        let link = builtin("sage_mb_link").unwrap();
        assert_eq!(link.train_inputs.len(), 9);
        assert_eq!(link.pred_inputs.len(), 6);
        assert_eq!(link.pred_output.shape, vec![256]);
        assert!(!link.params.iter().any(|p| p.name.starts_with("head.")));

        let recon = builtin("recon_c16_m32").unwrap();
        assert_eq!(recon.params.len(), 7);
        assert_eq!(recon.hyper_usize("batch").unwrap(), 512);

        let light = builtin("recon_light_c16_m32").unwrap();
        assert!(!light.params[0].trainable, "light variant freezes codebooks");
        assert_eq!(light.params[1].name, "dec.w0");

        assert!(builtin("node_fb_gcn_coded").is_none());
        for name in builtin_names() {
            assert!(builtin(name).is_some(), "{name} must synthesize");
        }
    }
}
