//! Native full-batch GNN grid (paper §5.2 / Table 1): GCN, SGC, GIN and
//! full-batch GraphSAGE over a **sparse CSR adjacency**, with the masked
//! softmax-CE node-classification head and the dot-product/BCE
//! link-prediction head. Mirrors `python/compile/gnn.py` layer for layer —
//! but where the HLO executables consume a dense `(n, n)` adjacency
//! tensor, this path propagates through [`Csr`] SpMM
//! ([`super::layers::spmm_par`] over [`Csr::spmm_row_major`]), so memory
//! and time scale with `nnz`, not `n²`.
//!
//! The adjacency is *bound*, not batched: the driver normalizes the graph
//! once (`sym_norm` / `row_norm` / `raw` per the manifest) and hands the
//! CSR to [`crate::runtime::Model::bind_adjacency`]; [`FbAdj`] keeps the
//! structural transpose alongside, because every hand-derived backward
//! needs `Aᵀ·dz` (`row_norm` is not symmetric).
//!
//! Determinism: all adjacency products partition output rows across
//! threads with fixed-order per-element reductions; gradient accumulation
//! (including the edge-scatter in the link head, which partitions
//! *gradient* rows and scans edges in order) follows the [`super::ops`]
//! rule, so training is bit-identical for every thread count.
#![allow(clippy::too_many_arguments)]

use std::sync::Arc;

use crate::runtime::{Manifest, Tensor};
use crate::sparse::Csr;
use crate::{Error, Result};

use super::decoder::find_param;
use super::layers::{spmm_par, FeatCache, FeatSource, LinearIdx};
use super::ops;
use super::par::par_rows;
use super::scratch::StepScratch;

/// Full-batch model dims.
#[derive(Clone, Copy, Debug)]
pub struct FbDims {
    pub n: usize,
    pub d_e: usize,
    pub hidden: usize,
}

/// A bound adjacency: the (normalized) matrix plus its structural
/// transpose for the reverse pass.
pub struct FbAdj {
    pub a: Arc<Csr>,
    pub at: Arc<Csr>,
}

impl FbAdj {
    pub fn new(a: Arc<Csr>) -> FbAdj {
        let at = Arc::new(a.transpose());
        FbAdj { a, at }
    }
}

/// One GCN layer with self-loop propagation and a linear skip connection:
/// `h' = relu(Â(h w) + h s + b)` (mirrors `gnn.py::gcn_apply`).
#[derive(Clone, Copy, Debug)]
pub struct GcnLayer {
    pub w: usize,
    pub s: usize,
    pub b: usize,
    pub d_in: usize,
    pub d_out: usize,
}

/// One GIN layer `relu-MLP((1 + ε)·h + A·h)` with trainable scalar ε
/// (mirrors `gnn.py::gin_apply`).
#[derive(Clone, Copy, Debug)]
pub struct GinLayer {
    pub eps: usize,
    pub a: LinearIdx,
    pub b: LinearIdx,
}

/// Resolved parameter indices for one §5.2 architecture.
pub enum FbGnn {
    Gcn { l1: GcnLayer, l2: GcnLayer },
    /// SGC (Wu et al. 2019): one linear map of `Â²x`, no nonlinearity.
    Sgc { lin: LinearIdx },
    Gin { l1: GinLayer, l2: GinLayer },
    /// Full-batch GraphSAGE: `h' = relu(W·concat(h, Āh) + b)` twice.
    Sage { l1: LinearIdx, l2: LinearIdx },
}

impl FbGnn {
    /// Resolve (and shape-check) the `gnn.*` parameters for `kind`,
    /// name-for-name against `python/compile/gnn.py`'s spec lists.
    pub fn resolve(manifest: &Manifest, kind: &str, d: usize, h: usize) -> Result<Self> {
        match kind {
            "gcn" => Ok(FbGnn::Gcn {
                l1: GcnLayer {
                    w: find_param(manifest, "gnn.w1", &[d, h])?,
                    s: find_param(manifest, "gnn.s1", &[d, h])?,
                    b: find_param(manifest, "gnn.b1", &[h])?,
                    d_in: d,
                    d_out: h,
                },
                l2: GcnLayer {
                    w: find_param(manifest, "gnn.w2", &[h, h])?,
                    s: find_param(manifest, "gnn.s2", &[h, h])?,
                    b: find_param(manifest, "gnn.b2", &[h])?,
                    d_in: h,
                    d_out: h,
                },
            }),
            "sgc" => Ok(FbGnn::Sgc { lin: LinearIdx::resolve(manifest, "gnn.w", "gnn.b", d, h)? }),
            "gin" => Ok(FbGnn::Gin {
                l1: GinLayer {
                    eps: find_param(manifest, "gnn.eps1", &[1])?,
                    a: LinearIdx::resolve(manifest, "gnn.m1a.w", "gnn.m1a.b", d, h)?,
                    b: LinearIdx::resolve(manifest, "gnn.m1b.w", "gnn.m1b.b", h, h)?,
                },
                l2: GinLayer {
                    eps: find_param(manifest, "gnn.eps2", &[1])?,
                    a: LinearIdx::resolve(manifest, "gnn.m2a.w", "gnn.m2a.b", h, h)?,
                    b: LinearIdx::resolve(manifest, "gnn.m2b.w", "gnn.m2b.b", h, h)?,
                },
            }),
            "sage" => Ok(FbGnn::Sage {
                l1: LinearIdx::resolve(manifest, "gnn.w1", "gnn.b1", 2 * d, h)?,
                l2: LinearIdx::resolve(manifest, "gnn.w2", "gnn.b2", 2 * h, h)?,
            }),
            other => Err(Error::Config(format!(
                "unknown full-batch gnn '{other}' (expected gcn | sgc | gin | sage)"
            ))),
        }
    }
}

/// Model-specific forward intermediates.
enum GnnCache {
    Gcn { h1: Vec<f32> },
    Sgc { a2x: Vec<f32> },
    Gin { z1: Vec<f32>, u1: Vec<f32>, h1: Vec<f32>, z2: Vec<f32>, u2: Vec<f32> },
    Sage { cat1: Vec<f32>, h1: Vec<f32>, cat2: Vec<f32> },
}

/// Full-batch encoder forward cache.
pub struct FbCache {
    feat: FeatCache,
    gnn: GnnCache,
    /// Final node representations `(n, hidden)`.
    pub h: Vec<f32>,
}

impl FbCache {
    /// Return every cached buffer to `scratch` once the step's backward
    /// pass has consumed the cache.
    pub fn recycle(self, scratch: &mut StepScratch) {
        let FbCache { feat, gnn, h } = self;
        feat.recycle(scratch);
        match gnn {
            GnnCache::Gcn { h1 } => scratch.give(h1),
            GnnCache::Sgc { a2x } => scratch.give(a2x),
            GnnCache::Gin { z1, u1, h1, z2, u2 } => scratch.give_all([z1, u1, h1, z2, u2]),
            GnnCache::Sage { cat1, h1, cat2 } => scratch.give_all([cat1, h1, cat2]),
        }
        scratch.give(h);
    }
}

// ---------------------------------------------------------------------------
// Per-architecture layers
// ---------------------------------------------------------------------------

fn gcn_layer_fwd(
    l: &GcnLayer,
    params: &[&[f32]],
    adj: &Csr,
    x: &[f32],
    n: usize,
    threads: usize,
    scratch: &mut StepScratch,
) -> Vec<f32> {
    let mut xw = scratch.take(n * l.d_out);
    ops::matmul_fwd(x, params[l.w], n, l.d_in, l.d_out, &mut xw, threads);
    let mut axw = scratch.take(n * l.d_out);
    spmm_par(adj, &xw, l.d_out, &mut axw, threads);
    scratch.give(xw);
    // z = (x s + b) + Â(x w), then ReLU — fixed summand order per element.
    let mut z = scratch.take(n * l.d_out);
    ops::linear_fwd(x, params[l.s], params[l.b], n, l.d_in, l.d_out, false, &mut z, threads);
    ops::add_assign(&mut z, &axw, threads);
    scratch.give(axw);
    ops::relu_inplace(&mut z, threads);
    z
}

/// Reverse of [`gcn_layer_fwd`] for `dz` (gradient at the post-ReLU
/// output); returns the gradient w.r.t. the layer input.
fn gcn_layer_bwd(
    l: &GcnLayer,
    params: &[&[f32]],
    adj_t: &Csr,
    x: &[f32],
    out_post: &[f32],
    mut dz: Vec<f32>,
    n: usize,
    trainable: &[bool],
    grads: &mut [Vec<f32>],
    threads: usize,
    scratch: &mut StepScratch,
) -> Vec<f32> {
    ops::relu_bwd_mask(&mut dz, out_post, threads);
    if trainable[l.b] {
        ops::grad_b(&dz, n, l.d_out, &mut grads[l.b]);
    }
    // Propagated branch: d(xw) = Âᵀ dz.
    let mut dq = scratch.take(n * l.d_out);
    spmm_par(adj_t, &dz, l.d_out, &mut dq, threads);
    if trainable[l.w] {
        ops::grad_w(x, &dq, n, l.d_in, l.d_out, &mut grads[l.w], threads);
    }
    if trainable[l.s] {
        ops::grad_w(x, &dz, n, l.d_in, l.d_out, &mut grads[l.s], threads);
    }
    let mut dx = scratch.take(n * l.d_in);
    ops::matmul_wt(&dq, params[l.w], n, l.d_in, l.d_out, false, &mut dx, threads);
    ops::matmul_wt(&dz, params[l.s], n, l.d_in, l.d_out, true, &mut dx, threads);
    scratch.give(dq);
    scratch.give(dz);
    dx
}

struct GinFwd {
    z: Vec<f32>,
    u: Vec<f32>,
    out: Vec<f32>,
}

fn gin_layer_fwd(
    l: &GinLayer,
    params: &[&[f32]],
    adj: &Csr,
    h_in: &[f32],
    n: usize,
    threads: usize,
    scratch: &mut StepScratch,
) -> GinFwd {
    let din = l.a.d_in;
    let eps = params[l.eps][0];
    let mut ah = scratch.take(n * din);
    spmm_par(adj, h_in, din, &mut ah, threads);
    let mut z = scratch.take(n * din);
    ops::scale_add(h_in, 1.0 + eps, &ah, &mut z, threads);
    scratch.give(ah);
    let mut u = scratch.take(n * l.a.d_out);
    l.a.fwd(params, &z, n, true, &mut u, threads);
    let mut out = scratch.take(n * l.b.d_out);
    l.b.fwd(params, &u, n, true, &mut out, threads);
    GinFwd { z, u, out }
}

/// Reverse of [`gin_layer_fwd`]; returns the gradient w.r.t. `h_in`.
fn gin_layer_bwd(
    l: &GinLayer,
    params: &[&[f32]],
    adj_t: &Csr,
    h_in: &[f32],
    z: &[f32],
    u: &[f32],
    out_post: &[f32],
    mut dout: Vec<f32>,
    n: usize,
    trainable: &[bool],
    grads: &mut [Vec<f32>],
    threads: usize,
    scratch: &mut StepScratch,
) -> Vec<f32> {
    let din = l.a.d_in;
    let eps = params[l.eps][0];
    ops::relu_bwd_mask(&mut dout, out_post, threads);
    let mut du = scratch.take(n * l.b.d_in);
    l.b.bwd(params, u, &dout, n, trainable, grads, Some(&mut du), false, threads);
    scratch.give(dout);
    ops::relu_bwd_mask(&mut du, u, threads);
    let mut dz = scratch.take(n * din);
    l.a.bwd(params, z, &du, n, trainable, grads, Some(&mut dz), false, threads);
    scratch.give(du);
    // z = (1 + ε) h + A h  ⇒  dε = ⟨dz, h⟩, dh = (1 + ε) dz + Aᵀ dz.
    if trainable[l.eps] {
        grads[l.eps][0] += ops::dot_all(&dz, h_in);
    }
    let mut adz = scratch.take(n * din);
    spmm_par(adj_t, &dz, din, &mut adz, threads);
    let mut dh = scratch.take(n * din);
    ops::scale_add(&dz, 1.0 + eps, &adz, &mut dh, threads);
    scratch.give(dz);
    scratch.give(adz);
    dh
}

/// [`gin_layer_fwd`] without the `z`/`u` cache — every intermediate is
/// dropped once consumed. Same kernels, bit-identical output.
fn gin_layer_infer(
    l: &GinLayer,
    params: &[&[f32]],
    adj: &Csr,
    h_in: &[f32],
    n: usize,
    threads: usize,
) -> Vec<f32> {
    let din = l.a.d_in;
    let eps = params[l.eps][0];
    let mut ah = vec![0.0f32; n * din];
    spmm_par(adj, h_in, din, &mut ah, threads);
    let mut z = vec![0.0f32; n * din];
    ops::scale_add(h_in, 1.0 + eps, &ah, &mut z, threads);
    drop(ah);
    let mut u = vec![0.0f32; n * l.a.d_out];
    l.a.fwd(params, &z, n, true, &mut u, threads);
    drop(z);
    let mut out = vec![0.0f32; n * l.b.d_out];
    l.b.fwd(params, &u, n, true, &mut out, threads);
    out
}

// ---------------------------------------------------------------------------
// Encoder
// ---------------------------------------------------------------------------

/// Encode all `n` nodes to `(n, hidden)` over the bound sparse adjacency.
/// `codes` is the all-node `(n, m)` codes tensor for the coded front-end,
/// `None` for the NC table.
pub fn encode_fwd(
    feat: &FeatSource,
    gnn: &FbGnn,
    dims: &FbDims,
    params: &[&[f32]],
    adj: &Csr,
    codes: Option<&Tensor>,
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<FbCache> {
    let (n, d, h) = (dims.n, dims.d_e, dims.hidden);
    if adj.n_rows() != n || adj.n_cols() != n {
        return Err(Error::Shape(format!(
            "bound adjacency is {}×{}, model wants {n}×{n}",
            adj.n_rows(),
            adj.n_cols()
        )));
    }
    let feat_cache = feat.fwd_full(params, codes, n, threads, scratch)?;
    let x = feat.output_full(&feat_cache, params);
    let (gnn_cache, hfin) = match gnn {
        FbGnn::Gcn { l1, l2 } => {
            let h1 = gcn_layer_fwd(l1, params, adj, x, n, threads, scratch);
            let h2 = gcn_layer_fwd(l2, params, adj, &h1, n, threads, scratch);
            (GnnCache::Gcn { h1 }, h2)
        }
        FbGnn::Sgc { lin } => {
            let mut ax = scratch.take(n * d);
            spmm_par(adj, x, d, &mut ax, threads);
            let mut a2x = scratch.take(n * d);
            spmm_par(adj, &ax, d, &mut a2x, threads);
            scratch.give(ax);
            let mut out = scratch.take(n * h);
            lin.fwd(params, &a2x, n, false, &mut out, threads);
            (GnnCache::Sgc { a2x }, out)
        }
        FbGnn::Gin { l1, l2 } => {
            let f1 = gin_layer_fwd(l1, params, adj, x, n, threads, scratch);
            let f2 = gin_layer_fwd(l2, params, adj, &f1.out, n, threads, scratch);
            (
                GnnCache::Gin { z1: f1.z, u1: f1.u, h1: f1.out, z2: f2.z, u2: f2.u },
                f2.out,
            )
        }
        FbGnn::Sage { l1, l2 } => {
            let mut ax = scratch.take(n * d);
            spmm_par(adj, x, d, &mut ax, threads);
            let mut cat1 = scratch.take(n * 2 * d);
            ops::scatter_cols(x, n, 2 * d, 0, d, &mut cat1, threads);
            ops::scatter_cols(&ax, n, 2 * d, d, d, &mut cat1, threads);
            scratch.give(ax);
            let mut h1 = scratch.take(n * h);
            l1.fwd(params, &cat1, n, true, &mut h1, threads);
            let mut ah1 = scratch.take(n * h);
            spmm_par(adj, &h1, h, &mut ah1, threads);
            let mut cat2 = scratch.take(n * 2 * h);
            ops::scatter_cols(&h1, n, 2 * h, 0, h, &mut cat2, threads);
            ops::scatter_cols(&ah1, n, 2 * h, h, h, &mut cat2, threads);
            scratch.give(ah1);
            let mut h2 = scratch.take(n * h);
            l2.fwd(params, &cat2, n, true, &mut h2, threads);
            (GnnCache::Sage { cat1, h1, cat2 }, h2)
        }
    };
    Ok(FbCache { feat: feat_cache, gnn: gnn_cache, h: hfin })
}

/// Inference-only full-graph encoder: all `n` final representations
/// `(n, hidden)` with **no cache** — intermediates are dropped as soon as
/// the next layer has consumed them, and nothing the reverse pass would
/// need survives. Same kernel sequence as [`encode_fwd`], so the output
/// is bit-identical to the training forward at every thread count.
pub fn encode_infer(
    feat: &FeatSource,
    gnn: &FbGnn,
    dims: &FbDims,
    params: &[&[f32]],
    adj: &Csr,
    codes: Option<&Tensor>,
    threads: usize,
) -> Result<Vec<f32>> {
    let (n, d, h) = (dims.n, dims.d_e, dims.hidden);
    if adj.n_rows() != n || adj.n_cols() != n {
        return Err(Error::Shape(format!(
            "bound adjacency is {}×{}, model wants {n}×{n}",
            adj.n_rows(),
            adj.n_cols()
        )));
    }
    let feats = feat.infer_full(params, codes, n, threads)?;
    let x = feats.as_slice();
    // Inference allocates fresh (a disabled scratch never pools), keeping
    // the no-cache / drop-as-consumed property of this path.
    let mut fresh = StepScratch::disabled();
    let hfin = match gnn {
        FbGnn::Gcn { l1, l2 } => {
            let h1 = gcn_layer_fwd(l1, params, adj, x, n, threads, &mut fresh);
            gcn_layer_fwd(l2, params, adj, &h1, n, threads, &mut fresh)
        }
        FbGnn::Sgc { lin } => {
            let mut ax = vec![0.0f32; n * d];
            spmm_par(adj, x, d, &mut ax, threads);
            let mut a2x = vec![0.0f32; n * d];
            spmm_par(adj, &ax, d, &mut a2x, threads);
            drop(ax);
            let mut out = vec![0.0f32; n * h];
            lin.fwd(params, &a2x, n, false, &mut out, threads);
            out
        }
        FbGnn::Gin { l1, l2 } => {
            let h1 = gin_layer_infer(l1, params, adj, x, n, threads);
            gin_layer_infer(l2, params, adj, &h1, n, threads)
        }
        FbGnn::Sage { l1, l2 } => {
            let h1 = {
                let mut ax = vec![0.0f32; n * d];
                spmm_par(adj, x, d, &mut ax, threads);
                let mut cat1 = vec![0.0f32; n * 2 * d];
                ops::scatter_cols(x, n, 2 * d, 0, d, &mut cat1, threads);
                ops::scatter_cols(&ax, n, 2 * d, d, d, &mut cat1, threads);
                drop(ax);
                let mut out = vec![0.0f32; n * h];
                l1.fwd(params, &cat1, n, true, &mut out, threads);
                out
            };
            let mut ah1 = vec![0.0f32; n * h];
            spmm_par(adj, &h1, h, &mut ah1, threads);
            let mut cat2 = vec![0.0f32; n * 2 * h];
            ops::scatter_cols(&h1, n, 2 * h, 0, h, &mut cat2, threads);
            ops::scatter_cols(&ah1, n, 2 * h, h, h, &mut cat2, threads);
            drop(ah1);
            drop(h1);
            let mut out = vec![0.0f32; n * h];
            l2.fwd(params, &cat2, n, true, &mut out, threads);
            out
        }
    };
    Ok(hfin)
}

/// Reverse pass of [`encode_fwd`] for `dh (n, hidden)`. Accumulates GNN
/// and front-end parameter gradients into `grads`.
pub fn encode_bwd(
    feat: &FeatSource,
    gnn: &FbGnn,
    dims: &FbDims,
    params: &[&[f32]],
    adj_t: &Csr,
    codes: Option<&Tensor>,
    cache: &FbCache,
    dh: Vec<f32>,
    trainable: &[bool],
    grads: &mut [Vec<f32>],
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<()> {
    let (n, d, h) = (dims.n, dims.d_e, dims.hidden);
    debug_assert_eq!(dh.len(), n * h);
    let x = feat.output_full(&cache.feat, params);
    let dx: Vec<f32> = match (gnn, &cache.gnn) {
        (FbGnn::Gcn { l1, l2 }, GnnCache::Gcn { h1 }) => {
            let dh1 = gcn_layer_bwd(
                l2, params, adj_t, h1, &cache.h, dh, n, trainable, grads, threads, scratch,
            );
            gcn_layer_bwd(l1, params, adj_t, x, h1, dh1, n, trainable, grads, threads, scratch)
        }
        (FbGnn::Sgc { lin }, GnnCache::Sgc { a2x }) => {
            let mut da2x = scratch.take(n * d);
            lin.bwd(params, a2x, &dh, n, trainable, grads, Some(&mut da2x), false, threads);
            scratch.give(dh);
            let mut dax = scratch.take(n * d);
            spmm_par(adj_t, &da2x, d, &mut dax, threads);
            scratch.give(da2x);
            let mut dx = scratch.take(n * d);
            spmm_par(adj_t, &dax, d, &mut dx, threads);
            scratch.give(dax);
            dx
        }
        (FbGnn::Gin { l1, l2 }, GnnCache::Gin { z1, u1, h1, z2, u2 }) => {
            let dh1 = gin_layer_bwd(
                l2, params, adj_t, h1, z2, u2, &cache.h, dh, n, trainable, grads, threads,
                scratch,
            );
            gin_layer_bwd(
                l1, params, adj_t, x, z1, u1, h1, dh1, n, trainable, grads, threads, scratch,
            )
        }
        (FbGnn::Sage { l1, l2 }, GnnCache::Sage { cat1, h1, cat2 }) => {
            let mut dz2 = dh;
            ops::relu_bwd_mask(&mut dz2, &cache.h, threads);
            let mut dcat2 = scratch.take(n * 2 * h);
            l2.bwd(params, cat2, &dz2, n, trainable, grads, Some(&mut dcat2), false, threads);
            scratch.give(dz2);
            // dh1 = dcat2[:, :h] + Âᵀ dcat2[:, h:].
            let mut dh1 = scratch.take(n * h);
            ops::gather_cols(&dcat2, n, 2 * h, 0, h, false, &mut dh1, threads);
            let mut dah1 = scratch.take(n * h);
            ops::gather_cols(&dcat2, n, 2 * h, h, h, false, &mut dah1, threads);
            scratch.give(dcat2);
            let mut tmp = scratch.take(n * h);
            spmm_par(adj_t, &dah1, h, &mut tmp, threads);
            scratch.give(dah1);
            ops::add_assign(&mut dh1, &tmp, threads);
            scratch.give(tmp);
            ops::relu_bwd_mask(&mut dh1, h1, threads);
            let mut dcat1 = scratch.take(n * 2 * d);
            l1.bwd(params, cat1, &dh1, n, trainable, grads, Some(&mut dcat1), false, threads);
            scratch.give(dh1);
            let mut dx = scratch.take(n * d);
            ops::gather_cols(&dcat1, n, 2 * d, 0, d, false, &mut dx, threads);
            let mut dax = scratch.take(n * d);
            ops::gather_cols(&dcat1, n, 2 * d, d, d, false, &mut dax, threads);
            scratch.give(dcat1);
            let mut tmp = scratch.take(n * d);
            spmm_par(adj_t, &dax, d, &mut tmp, threads);
            scratch.give(dax);
            ops::add_assign(&mut dx, &tmp, threads);
            scratch.give(tmp);
            dx
        }
        _ => return Err(Error::Runtime("full-batch cache/model mismatch".into())),
    };
    feat.bwd_full(params, codes, &cache.feat, &dx, trainable, grads, threads, scratch)?;
    scratch.give(dx);
    Ok(())
}

// ---------------------------------------------------------------------------
// Edge kernels (link head)
// ---------------------------------------------------------------------------

/// Validate `(e, 2)` edge endpoints against the node count.
pub(crate) fn validate_edges(edges: &[i32], n: usize) -> Result<()> {
    for &v in edges {
        if v < 0 || v as usize >= n {
            return Err(Error::Shape(format!("edge endpoint {v} out of range [0, {n})")));
        }
    }
    Ok(())
}

/// `out[e] = ⟨h[u_e], h[v_e]⟩` over `edges (e, 2)`. Shared with the
/// inference surface ([`super::infer`]), which scores edges over the same
/// representations.
pub(super) fn edge_dot(hmat: &[f32], edges: &[i32], d: usize, out: &mut [f32], threads: usize) {
    debug_assert_eq!(edges.len(), out.len() * 2);
    par_rows(out, 1, threads, |e0, part| {
        for (i, o) in part.iter_mut().enumerate() {
            let e = e0 + i;
            let u = edges[2 * e] as usize;
            let v = edges[2 * e + 1] as usize;
            let hu = &hmat[u * d..(u + 1) * d];
            let hv = &hmat[v * d..(v + 1) * d];
            let mut acc = 0.0f32;
            for (&a, &b) in hu.iter().zip(hv) {
                acc += a * b;
            }
            *o = acc;
        }
    });
}

/// Backward of [`edge_dot`]: `dh[u_e] += g_e·h[v_e]`, `dh[v_e] += g_e·h[u_e]`.
/// Threads partition the *gradient* rows; every worker scans all edges in
/// ascending order and accumulates only endpoints in its range, so the
/// per-element order is fixed for any thread count (no scatter races).
fn edge_dot_bwd(
    hmat: &[f32],
    edges: &[i32],
    dscore: &[f32],
    d: usize,
    dh: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(edges.len(), dscore.len() * 2);
    par_rows(dh, d, threads, |row0, rows| {
        let hi = row0 + rows.len() / d;
        for (e, &g) in dscore.iter().enumerate() {
            let u = edges[2 * e] as usize;
            let v = edges[2 * e + 1] as usize;
            if u >= row0 && u < hi {
                let grow = &mut rows[(u - row0) * d..(u - row0 + 1) * d];
                let hrow = &hmat[v * d..(v + 1) * d];
                for (o, &hv) in grow.iter_mut().zip(hrow) {
                    *o += g * hv;
                }
            }
            if v >= row0 && v < hi {
                let grow = &mut rows[(v - row0) * d..(v - row0 + 1) * d];
                let hrow = &hmat[u * d..(u + 1) * d];
                for (o, &hu) in grow.iter_mut().zip(hrow) {
                    *o += g * hu;
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Heads
// ---------------------------------------------------------------------------

/// Split a full-batch batch into its optional codes tensor and the rest.
pub(super) fn split_codes(coded: bool, batch: &[Tensor]) -> (Option<&Tensor>, &[Tensor]) {
    if coded {
        (Some(&batch[0]), &batch[1..])
    } else {
        (None, batch)
    }
}

/// Train-step gradients for full-batch node classification
/// (masked softmax CE over all `n` nodes). Batch: `codes?, labels, mask`.
pub fn clf_grads(
    feat: &FeatSource,
    gnn: &FbGnn,
    head: &LinearIdx,
    n_classes: usize,
    dims: &FbDims,
    coded: bool,
    params: &[&[f32]],
    adj: &FbAdj,
    batch: &[Tensor],
    trainable: &[bool],
    grads: &mut [Vec<f32>],
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<f32> {
    let (n, h) = (dims.n, dims.hidden);
    let (codes, rest) = split_codes(coded, batch);
    let labels = rest[0].as_i32()?;
    let mask = rest[1].as_f32()?;
    let cache = encode_fwd(feat, gnn, dims, params, &adj.a, codes, threads, scratch)?;
    let mut logits = scratch.take(n * n_classes);
    head.fwd(params, &cache.h, n, false, &mut logits, threads);
    let mut dlogits = scratch.take(n * n_classes);
    let loss = ops::masked_softmax_ce(&logits, labels, mask, n, n_classes, &mut dlogits, threads)?;
    scratch.give(logits);
    let mut dh = scratch.take(n * h);
    head.bwd(params, &cache.h, &dlogits, n, trainable, grads, Some(&mut dh), false, threads);
    scratch.give(dlogits);
    encode_bwd(
        feat, gnn, dims, params, &adj.at, codes, &cache, dh, trainable, grads, threads, scratch,
    )?;
    cache.recycle(scratch);
    Ok(loss)
}

/// Prediction for full-batch node classification: logits `(n, n_classes)`.
/// Batch: `codes?`.
pub fn clf_pred(
    feat: &FeatSource,
    gnn: &FbGnn,
    head: &LinearIdx,
    n_classes: usize,
    dims: &FbDims,
    coded: bool,
    params: &[&[f32]],
    adj: &Csr,
    batch: &[Tensor],
    threads: usize,
) -> Result<Vec<f32>> {
    let n = dims.n;
    let (codes, _rest) = split_codes(coded, batch);
    let h = encode_infer(feat, gnn, dims, params, adj, codes, threads)?;
    let mut logits = vec![0.0f32; n * n_classes];
    head.fwd(params, &h, n, false, &mut logits, threads);
    Ok(logits)
}

/// Train-step gradients for full-batch link prediction (dot-product
/// scorer, BCE over positive/negative edge batches). Batch:
/// `codes?, pos_edges (e, 2), neg_edges (e, 2)`.
pub fn link_grads(
    feat: &FeatSource,
    gnn: &FbGnn,
    dims: &FbDims,
    coded: bool,
    params: &[&[f32]],
    adj: &FbAdj,
    batch: &[Tensor],
    trainable: &[bool],
    grads: &mut [Vec<f32>],
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<f32> {
    let (n, h) = (dims.n, dims.hidden);
    let (codes, rest) = split_codes(coded, batch);
    let pos = rest[0].as_i32()?;
    let neg = rest[1].as_i32()?;
    validate_edges(pos, n)?;
    validate_edges(neg, n)?;
    let e = pos.len() / 2;
    let cache = encode_fwd(feat, gnn, dims, params, &adj.a, codes, threads, scratch)?;
    let mut pos_s = scratch.take(e);
    let mut neg_s = scratch.take(e);
    edge_dot(&cache.h, pos, h, &mut pos_s, threads);
    edge_dot(&cache.h, neg, h, &mut neg_s, threads);
    let mut dpos = scratch.take(e);
    let mut dneg = scratch.take(e);
    let loss = ops::bce_pair_loss(&pos_s, &neg_s, &mut dpos, &mut dneg);
    scratch.give_all([pos_s, neg_s]);
    let mut dh = scratch.take(n * h);
    // Fixed order: positive edges, then negative.
    edge_dot_bwd(&cache.h, pos, &dpos, h, &mut dh, threads);
    edge_dot_bwd(&cache.h, neg, &dneg, h, &mut dh, threads);
    scratch.give_all([dpos, dneg]);
    encode_bwd(
        feat, gnn, dims, params, &adj.at, codes, &cache, dh, trainable, grads, threads, scratch,
    )?;
    cache.recycle(scratch);
    Ok(loss)
}

/// Prediction for full-batch link prediction: scores `(e,)` for an edge
/// batch. Batch: `codes?, edges (e, 2)`.
pub fn link_pred(
    feat: &FeatSource,
    gnn: &FbGnn,
    dims: &FbDims,
    coded: bool,
    params: &[&[f32]],
    adj: &Csr,
    batch: &[Tensor],
    threads: usize,
) -> Result<Vec<f32>> {
    let (n, h) = (dims.n, dims.hidden);
    let (codes, rest) = split_codes(coded, batch);
    let edges = rest[0].as_i32()?;
    validate_edges(edges, n)?;
    let hmat = encode_infer(feat, gnn, dims, params, adj, codes, threads)?;
    let mut scores = vec![0.0f32; edges.len() / 2];
    edge_dot(&hmat, edges, h, &mut scores, threads);
    Ok(scores)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_dot_and_bwd_match_manual() {
        // 3 nodes, d = 2; h = [[1,0],[0,2],[3,1]].
        let h = vec![1.0, 0.0, 0.0, 2.0, 3.0, 1.0];
        let edges = vec![0, 1, 1, 2, 0, 2];
        let mut out = vec![0.0f32; 3];
        edge_dot(&h, &edges, 2, &mut out, 2);
        assert_eq!(out, vec![0.0, 2.0, 3.0]);
        let dscore = vec![1.0f32, 0.5, 2.0];
        let mut dh1 = vec![0.0f32; 6];
        edge_dot_bwd(&h, &edges, &dscore, 2, &mut dh1, 1);
        // node0: 1.0*h1 + 2.0*h2 = [0+6, 2+2] = [6, 4]
        // node1: 1.0*h0 + 0.5*h2 = [1+1.5, 0+0.5] = [2.5, 0.5]
        // node2: 0.5*h1 + 2.0*h0 = [0+2, 1+0] = [2, 1]
        assert_eq!(dh1, vec![6.0, 4.0, 2.5, 0.5, 2.0, 1.0]);
        // Thread invariance (bitwise).
        let mut dh4 = vec![0.0f32; 6];
        edge_dot_bwd(&h, &edges, &dscore, 2, &mut dh4, 4);
        assert!(dh1.iter().zip(&dh4).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(validate_edges(&edges, 3).is_ok());
        assert!(validate_edges(&[0, 3], 3).is_err());
        assert!(validate_edges(&[-1, 0], 3).is_err());
    }
}
