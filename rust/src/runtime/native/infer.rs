//! Inference-only model surface — the forward half of the fwd/bwd split.
//!
//! [`InferModel`] resolves the same [`Manifest`] contract as
//! [`super::NativeModel`] (shared resolver, shared parameter-index types)
//! but exposes **only** forward execution: no optimizer state, no
//! `trainable` masks, no gradient buffers, and no activation caches —
//! every path here goes through the `*_infer` forwards
//! ([`super::decoder::forward_infer`], [`super::sage::encode_infer`],
//! [`super::gnn::encode_infer`]), which drop intermediates as soon as the
//! next layer has consumed them. By construction nothing reachable from
//! this type can touch backward code.
//!
//! Because the inference forwards run the exact kernel sequence of the
//! train-fused forwards on the shared deterministic worker pool, every
//! result is **bit-identical** to the training-time forward at any thread
//! count — `tests/infer_parity.rs` asserts this for the decoder, the
//! minibatch SAGE heads, and all four full-batch architectures, including
//! the loss values ([`InferModel::loss`] vs. the fused train step).
//!
//! The serving layer's cross-request flush computes one deduplicated
//! node union through these forwards and scatters rows back per request
//! with [`demux_rows`] — the copy-only demux that makes batching
//! result-neutral by construction.
//!
//! Batch layouts per task (`hyper.task`):
//!
//! | task | [`embed_nodes`](InferModel::embed_nodes) | [`score_edges`](InferModel::score_edges) | [`predict_classes`](InferModel::predict_classes) |
//! |---|---|---|---|
//! | `recon` | `[codes (rows, m)]` → `(rows, d_e)` | `[codes_u, codes_v]` → `(rows,)` | — |
//! | `sage_minibatch[_link]` | 3 fan-out tensors → `(batch, hidden)` | 6 fan-out tensors (u then v) → `(batch,)` | 3 fan-out tensors → logits (clf only) |
//! | `*_fullbatch` | `[codes?]` → `(n, hidden)` | `[codes?, edges (e, 2)]` → `(e,)` | `[codes?]` → logits (clf only) |

use std::sync::{Arc, OnceLock};

use crate::runtime::{Manifest, Tensor};
use crate::sparse::Csr;
use crate::{Error, Result};

use super::gnn::{self, split_codes, validate_edges};
use super::layers::FeatSource;
use super::par::resolve_threads;
use super::{check_param_slices, normalize_manifest, ops, param_slices, resolve_task, sage, Task};

/// A manifest compiled for forward-only execution: resolved parameter
/// indices and dims, with no optimizer or gradient machinery attached.
pub struct InferModel {
    manifest: Manifest,
    task: Task,
    feat: FeatSource,
    /// Sparse adjacency for the full-batch tasks. Inference never needs
    /// the structural transpose the training model precomputes.
    adj: OnceLock<Arc<Csr>>,
}

impl InferModel {
    /// Build from a manifest (exported, synthesized by [`super::spec`], or
    /// carried by a [`crate::serve::ServingBundle`]). Validates every
    /// referenced parameter name/shape; any dense `adj` input spec is
    /// stripped exactly as the training model does.
    pub fn from_manifest(manifest: &Manifest) -> Result<Self> {
        let (task, feat) = resolve_task(manifest)?;
        let manifest = normalize_manifest(manifest, &task);
        Ok(Self { manifest, task, feat, adj: OnceLock::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn n_params(&self) -> usize {
        self.manifest.params.len()
    }

    /// Width of the representations [`Self::embed_nodes`] produces
    /// (`d_e` for the plain decoder, `hidden` for every GNN task).
    pub fn embed_dim(&self) -> usize {
        match &self.task {
            Task::Recon { d_e, .. } => *d_e,
            Task::SageClf { dims, .. } | Task::SageLink { dims, .. } => dims.hidden,
            Task::FbClf { dims, .. } | Task::FbLink { dims, .. } => dims.hidden,
        }
    }

    /// Natural request-batch size: the manifest batch for the minibatch
    /// tasks (their input shapes are fixed), the node count for full
    /// batch. The serving batcher coalesces queries into groups of this.
    pub fn serve_batch(&self) -> usize {
        match &self.task {
            Task::Recon { batch, .. } => *batch,
            Task::SageClf { dims, .. } | Task::SageLink { dims, .. } => dims.batch,
            Task::FbClf { dims, .. } | Task::FbLink { dims, .. } => dims.n,
        }
    }

    pub fn is_fullbatch(&self) -> bool {
        matches!(self.task, Task::FbClf { .. } | Task::FbLink { .. })
    }

    pub fn is_minibatch_sage(&self) -> bool {
        matches!(self.task, Task::SageClf { .. } | Task::SageLink { .. })
    }

    /// Fan-out widths `(k1, k2)` for the minibatch SAGE tasks.
    pub fn fanout(&self) -> Option<(usize, usize)> {
        match &self.task {
            Task::SageClf { dims, .. } | Task::SageLink { dims, .. } => Some((dims.k1, dims.k2)),
            _ => None,
        }
    }

    /// Whether the front-end consumes compositional codes (vs. node ids
    /// into an explicit table).
    pub fn coded(&self) -> bool {
        matches!(self.feat, FeatSource::Decoder { .. })
    }

    /// Code length `m` of the coded front-end.
    pub fn code_m(&self) -> Option<usize> {
        match &self.feat {
            FeatSource::Decoder { dims, .. } => Some(dims.m),
            FeatSource::Table { .. } | FeatSource::HashEmb { .. } => None,
        }
    }

    /// Does this model's front-end need [`Self::bind_pos_map`] before it
    /// can run? (Only the poshash hash front-end does.)
    pub fn needs_pos_map(&self) -> bool {
        self.feat.needs_pos_map()
    }

    /// Bind the poshash front-end's degree-rank bucket map — same contract
    /// as the training model's bind (rebind-equal is a no-op, other
    /// front-ends refuse).
    pub fn bind_pos_map(&self, map: Arc<Vec<u32>>) -> Result<()> {
        self.feat.bind_pos_map(map)
    }

    /// Classes of the classification head, when the task has one.
    pub fn n_classes(&self) -> Option<usize> {
        match &self.task {
            Task::SageClf { n_classes, .. } | Task::FbClf { n_classes, .. } => Some(*n_classes),
            _ => None,
        }
    }

    /// Bind the (already normalized) sparse adjacency for a full-batch
    /// model — same contract as the training model's bind, minus the
    /// transpose precompute the backward pass would need.
    pub fn bind_adjacency(&self, adj: Arc<Csr>) -> Result<()> {
        let n = match &self.task {
            Task::FbClf { dims, .. } | Task::FbLink { dims, .. } => dims.n,
            _ => {
                return Err(Error::Runtime(format!(
                    "model '{}' is not a full-batch task — only nodeclf_fullbatch / \
                     linkpred_fullbatch take a CSR adjacency",
                    self.manifest.name
                )))
            }
        };
        if adj.n_rows() != n || adj.n_cols() != n {
            return Err(Error::Shape(format!(
                "adjacency is {}×{}, model '{}' wants {n}×{n}",
                adj.n_rows(),
                adj.n_cols(),
                self.manifest.name
            )));
        }
        if let Some(existing) = self.adj.get() {
            if Arc::ptr_eq(existing, &adj) || **existing == *adj {
                return Ok(());
            }
            return Err(Error::Runtime(format!(
                "model '{}' already has a different bound adjacency",
                self.manifest.name
            )));
        }
        self.adj.set(adj).map_err(|_| {
            Error::Runtime(format!(
                "model '{}': concurrent adjacency binds raced — bind once before inference",
                self.manifest.name
            ))
        })
    }

    fn bound_adj(&self) -> Result<&Arc<Csr>> {
        self.adj.get().ok_or_else(|| {
            Error::Runtime(format!(
                "full-batch model '{}' has no adjacency bound — call \
                 InferModel::bind_adjacency with the normalized graph CSR before inference",
                self.manifest.name
            ))
        })
    }

    fn slices<'a>(&self, params: &'a [Tensor]) -> Result<Vec<&'a [f32]>> {
        param_slices(&self.manifest, params)
    }

    /// Node representations for one batch (layout per the module table).
    /// Bit-identical to the training forward's representations.
    pub fn embed_nodes(&self, params: &[Tensor], batch: &[Tensor], threads: usize) -> Result<Tensor> {
        self.embed_nodes_with(&self.slices(params)?, batch, threads)
    }

    /// [`Self::embed_nodes`] over pre-sliced parameter data — the form a
    /// zero-copy [`crate::serve::ServingBundle`] hands out (borrowed
    /// `&[f32]` views of its file image, no [`Tensor`] materialized).
    /// Identical kernels, identical results.
    pub fn embed_nodes_with(
        &self,
        params: &[&[f32]],
        batch: &[Tensor],
        threads: usize,
    ) -> Result<Tensor> {
        check_param_slices(&self.manifest, params)?;
        let slices = params;
        let threads = resolve_threads(threads);
        match &self.task {
            Task::Recon { d_e, .. } => {
                need_tensors("recon embed_nodes", batch, 1)?;
                let out = self.feat.infer(&slices, &batch[0], threads)?;
                let rows = out.len() / d_e;
                Tensor::f32(vec![rows, *d_e], out)
            }
            Task::SageClf { sage, dims, .. } | Task::SageLink { sage, dims } => {
                need_tensors("sage embed_nodes", batch, 3)?;
                let h = sage::encode_infer(
                    &self.feat, sage, dims, &slices, &batch[0], &batch[1], &batch[2], threads,
                )?;
                Tensor::f32(vec![dims.batch, dims.hidden], h)
            }
            Task::FbClf { gnn, dims, coded, .. } | Task::FbLink { gnn, dims, coded } => {
                need_tensors("full-batch embed_nodes", batch, usize::from(*coded))?;
                let (codes, _rest) = split_codes(*coded, batch);
                let h = gnn::encode_infer(
                    &self.feat, gnn, dims, &slices, self.bound_adj()?, codes, threads,
                )?;
                Tensor::f32(vec![dims.n, dims.hidden], h)
            }
        }
    }

    /// Edge scores — dot products of the two endpoint representations,
    /// matching the training link heads bit for bit.
    pub fn score_edges(&self, params: &[Tensor], batch: &[Tensor], threads: usize) -> Result<Tensor> {
        self.score_edges_with(&self.slices(params)?, batch, threads)
    }

    /// [`Self::score_edges`] over pre-sliced parameter data (see
    /// [`Self::embed_nodes_with`]).
    pub fn score_edges_with(
        &self,
        params: &[&[f32]],
        batch: &[Tensor],
        threads: usize,
    ) -> Result<Tensor> {
        check_param_slices(&self.manifest, params)?;
        let slices = params;
        let threads = resolve_threads(threads);
        match &self.task {
            Task::Recon { d_e, .. } => {
                need_tensors("recon score_edges", batch, 2)?;
                let u = self.feat.infer(&slices, &batch[0], threads)?;
                let v = self.feat.infer(&slices, &batch[1], threads)?;
                if u.len() != v.len() {
                    return Err(Error::Shape(format!(
                        "score_edges: {} u-rows vs {} v-rows",
                        u.len() / d_e,
                        v.len() / d_e
                    )));
                }
                let rows = u.len() / d_e;
                let mut scores = vec![0.0f32; rows];
                ops::dot_rows(&u, &v, rows, *d_e, &mut scores, threads);
                Tensor::f32(vec![rows], scores)
            }
            Task::SageClf { sage, dims, .. } | Task::SageLink { sage, dims } => {
                need_tensors("sage score_edges", batch, 6)?;
                let hu = sage::encode_infer(
                    &self.feat, sage, dims, &slices, &batch[0], &batch[1], &batch[2], threads,
                )?;
                let hv = sage::encode_infer(
                    &self.feat, sage, dims, &slices, &batch[3], &batch[4], &batch[5], threads,
                )?;
                let mut scores = vec![0.0f32; dims.batch];
                ops::dot_rows(&hu, &hv, dims.batch, dims.hidden, &mut scores, threads);
                Tensor::f32(vec![dims.batch], scores)
            }
            Task::FbClf { gnn, dims, coded, .. } | Task::FbLink { gnn, dims, coded } => {
                need_tensors("full-batch score_edges", batch, usize::from(*coded) + 1)?;
                let (codes, rest) = split_codes(*coded, batch);
                let edges = rest[0].as_i32()?;
                validate_edges(edges, dims.n)?;
                let h = gnn::encode_infer(
                    &self.feat, gnn, dims, &slices, self.bound_adj()?, codes, threads,
                )?;
                let mut scores = vec![0.0f32; edges.len() / 2];
                gnn::edge_dot(&h, edges, dims.hidden, &mut scores, threads);
                Tensor::f32(vec![edges.len() / 2], scores)
            }
        }
    }

    /// Class logits for the tasks that carry a classification head
    /// (`sage_minibatch`, `nodeclf_fullbatch`); errors otherwise.
    pub fn predict_classes(
        &self,
        params: &[Tensor],
        batch: &[Tensor],
        threads: usize,
    ) -> Result<Tensor> {
        self.predict_classes_with(&self.slices(params)?, batch, threads)
    }

    /// [`Self::predict_classes`] over pre-sliced parameter data (see
    /// [`Self::embed_nodes_with`]).
    pub fn predict_classes_with(
        &self,
        params: &[&[f32]],
        batch: &[Tensor],
        threads: usize,
    ) -> Result<Tensor> {
        check_param_slices(&self.manifest, params)?;
        let slices = params;
        let threads = resolve_threads(threads);
        match &self.task {
            Task::SageClf { sage, head, n_classes, dims } => {
                need_tensors("sage predict_classes", batch, 3)?;
                let h = sage::encode_infer(
                    &self.feat, sage, dims, &slices, &batch[0], &batch[1], &batch[2], threads,
                )?;
                let mut logits = vec![0.0f32; dims.batch * n_classes];
                head.fwd(&slices, &h, dims.batch, false, &mut logits, threads);
                Tensor::f32(vec![dims.batch, *n_classes], logits)
            }
            Task::FbClf { gnn, head, n_classes, dims, coded } => {
                need_tensors("full-batch predict_classes", batch, usize::from(*coded))?;
                let (codes, _rest) = split_codes(*coded, batch);
                let h = gnn::encode_infer(
                    &self.feat, gnn, dims, &slices, self.bound_adj()?, codes, threads,
                )?;
                let mut logits = vec![0.0f32; dims.n * n_classes];
                head.fwd(&slices, &h, dims.n, false, &mut logits, threads);
                Tensor::f32(vec![dims.n, *n_classes], logits)
            }
            _ => Err(Error::Runtime(format!(
                "model '{}' has no classification head",
                self.manifest.name
            ))),
        }
    }

    /// Apply the classification head to already-computed representations
    /// `h (rows, hidden)` — the path the serving cache uses after a hit.
    /// Row-wise, so the logits are bit-identical to running the head over
    /// any batch containing the same rows.
    pub fn head_logits(
        &self,
        params: &[Tensor],
        h: &[f32],
        rows: usize,
        threads: usize,
    ) -> Result<Vec<f32>> {
        self.head_logits_with(&self.slices(params)?, h, rows, threads)
    }

    /// [`Self::head_logits`] over pre-sliced parameter data (see
    /// [`Self::embed_nodes_with`]).
    pub fn head_logits_with(
        &self,
        params: &[&[f32]],
        h: &[f32],
        rows: usize,
        threads: usize,
    ) -> Result<Vec<f32>> {
        let (head, n_classes, hidden) = match &self.task {
            Task::SageClf { head, n_classes, dims, .. } => (head, *n_classes, dims.hidden),
            Task::FbClf { head, n_classes, dims, .. } => (head, *n_classes, dims.hidden),
            _ => {
                return Err(Error::Runtime(format!(
                    "model '{}' has no classification head",
                    self.manifest.name
                )))
            }
        };
        if h.len() != rows * hidden {
            return Err(Error::Shape(format!(
                "head_logits: {} elements for {rows} rows of hidden={hidden}",
                h.len()
            )));
        }
        check_param_slices(&self.manifest, params)?;
        let slices = params;
        let threads = resolve_threads(threads);
        let mut logits = vec![0.0f32; rows * n_classes];
        head.fwd(&slices, h, rows, false, &mut logits, threads);
        Ok(logits)
    }

    /// Forward-only training loss over one full train batch (layout =
    /// `manifest.train_inputs`) — the value the fused train step would
    /// emit for the same parameters and batch, bit for bit, with no
    /// gradient buffer allocated anywhere. Exists so inference/training
    /// parity is testable end to end.
    pub fn loss(&self, params: &[Tensor], batch: &[Tensor], threads: usize) -> Result<f32> {
        super::validate_specs(batch, &self.manifest.train_inputs)?;
        let slices = self.slices(params)?;
        let threads = resolve_threads(threads);
        match &self.task {
            Task::Recon { .. } => {
                let out = self.feat.infer(&slices, &batch[0], threads)?;
                let target = batch[1].as_f32()?;
                Ok(ops::mse_loss(&out, target))
            }
            Task::SageClf { sage, head, n_classes, dims } => {
                let h = sage::encode_infer(
                    &self.feat, sage, dims, &slices, &batch[0], &batch[1], &batch[2], threads,
                )?;
                let mut logits = vec![0.0f32; dims.batch * n_classes];
                head.fwd(&slices, &h, dims.batch, false, &mut logits, threads);
                ops::softmax_ce_loss(&logits, batch[3].as_i32()?, dims.batch, *n_classes, threads)
            }
            Task::SageLink { sage, dims } => {
                let hu = sage::encode_infer(
                    &self.feat, sage, dims, &slices, &batch[0], &batch[1], &batch[2], threads,
                )?;
                let hv = sage::encode_infer(
                    &self.feat, sage, dims, &slices, &batch[3], &batch[4], &batch[5], threads,
                )?;
                let hw = sage::encode_infer(
                    &self.feat, sage, dims, &slices, &batch[6], &batch[7], &batch[8], threads,
                )?;
                let mut pos = vec![0.0f32; dims.batch];
                let mut neg = vec![0.0f32; dims.batch];
                ops::dot_rows(&hu, &hv, dims.batch, dims.hidden, &mut pos, threads);
                ops::dot_rows(&hu, &hw, dims.batch, dims.hidden, &mut neg, threads);
                Ok(ops::bpr_loss_value(&pos, &neg))
            }
            Task::FbClf { gnn, head, n_classes, dims, coded } => {
                let (codes, rest) = split_codes(*coded, batch);
                let labels = rest[0].as_i32()?;
                let mask = rest[1].as_f32()?;
                let h = gnn::encode_infer(
                    &self.feat, gnn, dims, &slices, self.bound_adj()?, codes, threads,
                )?;
                let mut logits = vec![0.0f32; dims.n * n_classes];
                head.fwd(&slices, &h, dims.n, false, &mut logits, threads);
                ops::masked_softmax_ce_loss(&logits, labels, mask, dims.n, *n_classes, threads)
            }
            Task::FbLink { gnn, dims, coded } => {
                let (codes, rest) = split_codes(*coded, batch);
                let pos_e = rest[0].as_i32()?;
                let neg_e = rest[1].as_i32()?;
                validate_edges(pos_e, dims.n)?;
                validate_edges(neg_e, dims.n)?;
                let h = gnn::encode_infer(
                    &self.feat, gnn, dims, &slices, self.bound_adj()?, codes, threads,
                )?;
                let e = pos_e.len() / 2;
                let mut pos = vec![0.0f32; e];
                let mut neg = vec![0.0f32; e];
                gnn::edge_dot(&h, pos_e, dims.hidden, &mut pos, threads);
                gnn::edge_dot(&h, neg_e, dims.hidden, &mut neg, threads);
                Ok(ops::bce_pair_loss_value(&pos, &neg))
            }
        }
    }
}

fn need_tensors(what: &str, batch: &[Tensor], n: usize) -> Result<()> {
    if batch.len() != n {
        return Err(Error::Shape(format!("{what}: got {} tensors, need {n}", batch.len())));
    }
    Ok(())
}

/// Scatter rows computed for a **deduplicated** id list back onto an
/// arbitrary (possibly repeating, arbitrarily ordered) query — the batch
/// demux the serving layer runs after a cross-request flush. `rows` is
/// row-major `(unique.len(), d)`; `out` must be `query.len() × d` and
/// receives, for each query slot, a verbatim copy of its id's row.
///
/// Copying is the whole point: the flush computes each distinct node
/// once, and every request that referenced it gets byte-identical data,
/// so batching and deduplication can never change a served value.
///
/// ```
/// use hashgnn::runtime::native::infer::demux_rows;
///
/// let unique = [7u32, 3, 9];
/// let rows = [0.7, 0.7, 0.3, 0.3, 0.9, 0.9]; // (3, 2) for nodes 7, 3, 9
/// let mut out = vec![0.0f32; 4 * 2];
/// demux_rows(&unique, &rows, 2, &[3, 7, 3, 9], &mut out).unwrap();
/// assert_eq!(out, [0.3, 0.3, 0.7, 0.7, 0.3, 0.3, 0.9, 0.9]);
/// ```
pub fn demux_rows(
    unique: &[u32],
    rows: &[f32],
    d: usize,
    query: &[u32],
    out: &mut [f32],
) -> Result<()> {
    if rows.len() != unique.len() * d {
        return Err(Error::Shape(format!(
            "demux_rows: {} row values for {} unique ids of width {d}",
            rows.len(),
            unique.len()
        )));
    }
    demux_rows_with(&row_index(unique), rows, d, query, out)
}

/// The id → row lookup table of a deduplicated id list. Build it once
/// per flush and reuse it across every request's [`demux_rows_with`]
/// call — rebuilding it per request would redo O(unique) work per
/// pending request on the hot serving path.
pub fn row_index(unique: &[u32]) -> std::collections::HashMap<u32, usize> {
    let mut map = std::collections::HashMap::with_capacity(unique.len());
    row_index_into(unique, &mut map);
    map
}

/// [`row_index`] into a caller-owned map — clears and refills, so a
/// serving session can keep one map (and its grown table) alive across
/// flushes instead of allocating a fresh one per flush.
pub fn row_index_into(unique: &[u32], map: &mut std::collections::HashMap<u32, usize>) {
    map.clear();
    map.reserve(unique.len());
    for (k, &id) in unique.iter().enumerate() {
        map.insert(id, k);
    }
}

/// [`demux_rows`] against a prebuilt [`row_index`].
pub fn demux_rows_with(
    index: &std::collections::HashMap<u32, usize>,
    rows: &[f32],
    d: usize,
    query: &[u32],
    out: &mut [f32],
) -> Result<()> {
    if out.len() != query.len() * d {
        return Err(Error::Shape(format!(
            "demux_rows: output holds {} values, query needs {}",
            out.len(),
            query.len() * d
        )));
    }
    for (slot, id) in query.iter().enumerate() {
        let k = *index.get(id).ok_or_else(|| {
            Error::Shape(format!("demux_rows: query id {id} missing from the computed union"))
        })?;
        if (k + 1) * d > rows.len() {
            return Err(Error::Shape(format!(
                "demux_rows: index row {k} out of bounds for {} row values of width {d}",
                rows.len()
            )));
        }
        out[slot * d..(slot + 1) * d].copy_from_slice(&rows[k * d..(k + 1) * d]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamStore;
    use crate::runtime::native::spec;

    fn recon_manifest() -> Manifest {
        spec::ReconBuild {
            name: "inf_recon".into(),
            c: 4,
            m: 3,
            d_c: 5,
            d_m: 6,
            d_e: 2,
            l: 2,
            light: false,
            batch: 4,
            optim: crate::cfg::OptimCfg::adamw_default(),
        }
        .manifest()
    }

    #[test]
    fn recon_embed_and_score_shapes() {
        let m = recon_manifest();
        let model = InferModel::from_manifest(&m).unwrap();
        assert_eq!(model.embed_dim(), 2);
        assert_eq!(model.serve_batch(), 4);
        assert!(model.coded());
        assert_eq!(model.code_m(), Some(3));
        assert_eq!(model.n_classes(), None);
        let store = ParamStore::init(&m, 3);
        let codes = Tensor::i32(vec![4, 3], vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3]).unwrap();
        let emb = model.embed_nodes(&store.params, &[codes.clone()], 2).unwrap();
        assert_eq!(emb.shape(), &[4, 2]);
        let scores = model.score_edges(&store.params, &[codes.clone(), codes.clone()], 1).unwrap();
        assert_eq!(scores.shape(), &[4]);
        // An edge to itself scores the squared norm of its embedding.
        let e = emb.as_f32().unwrap();
        let s = scores.as_f32().unwrap();
        for r in 0..4 {
            let manual = e[r * 2] * e[r * 2] + e[r * 2 + 1] * e[r * 2 + 1];
            assert_eq!(s[r].to_bits(), manual.to_bits());
        }
        assert!(model.predict_classes(&store.params, &[codes], 1).is_err());
    }

    #[test]
    fn fullbatch_requires_bound_adjacency() {
        let m = spec::builtin("node_fb_sgc_nc").unwrap();
        let model = InferModel::from_manifest(&m).unwrap();
        let store = ParamStore::init(&m, 3);
        let err = model.embed_nodes(&store.params, &[], 1).unwrap_err();
        assert!(format!("{err}").contains("bind_adjacency"), "{err}");
        let n = m.hyper_usize("n").unwrap();
        let adj = Arc::new(Csr::from_edges(n, &[(0, 1), (1, 2)]).unwrap());
        model.bind_adjacency(adj.clone()).unwrap();
        assert!(model.bind_adjacency(adj).is_ok(), "rebinding same matrix is a no-op");
        let other = Arc::new(Csr::from_edges(n, &[(4, 5)]).unwrap());
        assert!(model.bind_adjacency(other).is_err());
        let emb = model.embed_nodes(&store.params, &[], 2).unwrap();
        assert_eq!(emb.shape(), &[n, m.hyper_usize("hidden").unwrap()]);
    }

    #[test]
    fn demux_rows_copies_and_validates() {
        let unique = [4u32, 1];
        let rows = [1.0f32, 2.0, 3.0, 4.0];
        let mut out = vec![0.0f32; 3 * 2];
        demux_rows(&unique, &rows, 2, &[1, 4, 1], &mut out).unwrap();
        assert_eq!(out, vec![3.0, 4.0, 1.0, 2.0, 3.0, 4.0]);
        // Missing id, bad row count, bad out size — all loud.
        assert!(demux_rows(&unique, &rows, 2, &[9], &mut out[..2]).is_err());
        assert!(demux_rows(&unique, &rows[..3], 2, &[1], &mut out[..2]).is_err());
        assert!(demux_rows(&unique, &rows, 2, &[1], &mut out).is_err());
    }

    #[test]
    fn unknown_task_rejected() {
        let mut m = recon_manifest();
        if let crate::ser::Json::Obj(o) = &mut m.hyper {
            o.insert("task".into(), crate::ser::Json::str("transformer"));
        }
        assert!(InferModel::from_manifest(&m).is_err());
    }
}
