//! Minimal property-testing harness (the offline crate set has no
//! `proptest`). Runs a property over many seeded random cases; on failure
//! reports the failing case index and seed so it can be replayed exactly.
//!
//! Used by `rust/tests/prop_invariants.rs` for coordinator invariants
//! (routing/batching/state per the session testing contract).

use crate::rng::Xoshiro256pp;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 100, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cfg.cases` independently seeded RNGs. The property
/// returns `Err(reason)` to fail. Panics with a replayable report.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Xoshiro256pp) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Xoshiro256pp::seed_from_u64(case_seed);
        if let Err(reason) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{} (replay seed: {case_seed:#x}): {reason}",
                cfg.cases
            );
        }
    }
}

/// Shorthand for a default-config check.
pub fn quickcheck<F>(name: &str, prop: F)
where
    F: FnMut(&mut Xoshiro256pp) -> Result<(), String>,
{
    check(name, PropConfig::default(), prop);
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("count", PropConfig { cases: 17, seed: 5 }, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        quickcheck("always-fails", |rng| {
            let x = rng.index(10);
            if x < 10 {
                Err("x is always < 10".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn allclose_behaviour() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-5, 1e-5).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-5).is_err());
    }
}
