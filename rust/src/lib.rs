//! # hashgnn
//!
//! Reproduction of *"Embedding Compression with Hashing for Efficient
//! Representation Learning in Large-Scale Graph"* (Yeh et al., KDD 2022).
//!
//! The library replaces a GNN's `n × d_e` input-embedding table with:
//!
//! 1. an **encoding stage** ([`lsh`]) that assigns every node an
//!    `m·log2(c)`-bit compositional code via random-projection LSH over
//!    auxiliary information (adjacency rows or pre-trained embeddings),
//!    binarized at the **median** to minimize collisions (Algorithm 1).
//!    The encode path is a deterministic multi-threaded engine
//!    ([`lsh::encode_with`]): per-bit seed streams, a blocked CSR SpMM,
//!    parallel medians and word-packed bit writes — output is
//!    bit-identical for every thread count and block size; and
//! 2. a **decoding stage** executed through [`runtime`] — a decoder that
//!    maps codes through `m` codebooks + an MLP to dense embeddings,
//!    trained jointly with the GNN (paper §4, Eq. 5–6).
//!
//! The runtime is a **backend dispatch**: the pure-Rust native engine
//! ([`runtime::native`] — forward, hand-derived reverse-mode backward,
//! fused AdamW, deterministic kernels on a process-wide worker pool) runs
//! every model family with zero artifacts — the §4 minibatch
//! hash-embedding + GraphSAGE pipeline *and* the full §5.2 Table-1 grid
//! (full-batch GCN / SGC / GIN / SAGE, node classification and link
//! prediction, propagating over **sparse CSR adjacency** bound via
//! [`runtime::Model::bind_adjacency`] — no dense `n×n` tensor on the
//! native path). The same models can execute as AOT-compiled JAX/Pallas
//! HLO via PJRT when `make artifacts` has run and the `xla` feature is
//! on. Layer 3 (this crate) owns the whole request/training path: graph
//! substrates, code generation, batch pipelines, backend execution,
//! parameter state, metrics, and the experiment drivers that regenerate
//! every table and figure of the paper. Python/JAX is build-time only,
//! and optional.
//!
//! ## Module map
//!
//! | layer | modules |
//! |---|---|
//! | substrates | [`rng`] (incl. stream splitting), [`ser`], [`cli`], [`cfg`] (incl. [`cfg::BackendKind`]), [`sparse`] (SpMV, blocked SpMM, row-major SpMM, transpose, sparse normalizations), [`graph`], [`embed`] |
//! | paper core | [`lsh`] (Algorithm 1 + parallel encode engine), [`codes`] (compositional codes, word-packed bits) |
//! | runtime    | [`runtime`] (backend seam: [`runtime::native`] pure-Rust train/pred engine — [`runtime::native::layers`] shared blocks, [`runtime::native::sage`] minibatch encoder, [`runtime::native::gnn`] full-batch grid, [`runtime::native::infer`] forward-only inference surface — + PJRT HLO path; in-crate [`xla`] stub unless the `xla` feature is on), [`params`], [`train`] |
//! | serving    | [`serve`] (frozen [`serve::ServingBundle`] artifact + node-range shards, request [`serve::Batcher`] / cross-request [`serve::CrossBatcher`], exact-LRU [`serve::EmbedCache`], [`serve::ServeSession`] / [`serve::ShardRouter`] behind the [`serve::Serving`] seam, persistent NDJSON/TCP loop in [`serve::server`] — `hashgnn export [--shards K]` / `infer` / `serve --oneshot|--stdin|--listen`; no backward code reachable) |
//! | evaluation | [`eval`], [`tasks`], [`report`] |
//! | dev        | [`testing`] (property-test harness) |
//!
//! Repo-level docs: `docs/ARCHITECTURE.md` maps the four subsystems,
//! their seams, the determinism rule and the binary format family;
//! `docs/SERVING.md` specifies the serving wire protocol end to end.

pub mod cfg;
pub mod cli;
pub mod codes;
pub mod embed;
pub mod eval;
pub mod graph;
pub mod lsh;
pub mod params;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod ser;
pub mod serve;
pub mod sparse;
pub mod tasks;
pub mod testing;
pub mod train;

/// Host-only stand-in for the `xla` PJRT binding crate, compiled when the
/// default-off `xla` feature is disabled (the offline build). See
/// `rust/Cargo.toml` for how to wire in a real binding.
#[cfg(not(feature = "xla"))]
#[path = "xla_stub.rs"]
pub mod xla;

/// Crate-wide error type. Display/Error are implemented by hand — the
/// offline crate set has no `thiserror`.
#[derive(Debug)]
pub enum Error {
    Config(String),
    Shape(String),
    Io(std::io::Error),
    Json(String),
    Runtime(String),
    Xla(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_matches_previous_derive_format() {
        assert_eq!(format!("{}", Error::Config("bad c".into())), "config error: bad c");
        assert_eq!(format!("{}", Error::Shape("2x3".into())), "shape mismatch: 2x3");
        assert_eq!(format!("{}", Error::Json("eof".into())), "json error: eof");
        assert_eq!(format!("{}", Error::Runtime("no artifact".into())), "runtime error: no artifact");
        assert_eq!(format!("{}", Error::Xla("stub".into())), "xla error: stub");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e}").contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
