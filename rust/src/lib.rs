//! # hashgnn
//!
//! Reproduction of *"Embedding Compression with Hashing for Efficient
//! Representation Learning in Large-Scale Graph"* (Yeh et al., KDD 2022).
//!
//! The library replaces a GNN's `n × d_e` input-embedding table with:
//!
//! 1. an **encoding stage** ([`lsh`]) that assigns every node an
//!    `m·log2(c)`-bit compositional code via random-projection LSH over
//!    auxiliary information (adjacency rows or pre-trained embeddings),
//!    binarized at the **median** to minimize collisions (Algorithm 1), and
//! 2. a **decoding stage** (AOT-compiled JAX/Pallas, executed through
//!    [`runtime`]) that maps codes through `m` codebooks + an MLP to dense
//!    embeddings, trained end-to-end with the GNN.
//!
//! Layer 3 (this crate) owns the whole request/training path: graph
//! substrates, code generation, batch pipelines, PJRT execution, parameter
//! state, metrics, and the experiment drivers that regenerate every table
//! and figure of the paper. Python/JAX runs only at build time
//! (`make artifacts`).
//!
//! ## Module map
//!
//! | layer | modules |
//! |---|---|
//! | substrates | [`rng`], [`ser`], [`cli`], [`cfg`], [`sparse`], [`graph`], [`embed`] |
//! | paper core | [`lsh`] (Algorithm 1), [`codes`] (compositional codes) |
//! | runtime    | [`runtime`] (PJRT), [`params`], [`train`] |
//! | evaluation | [`eval`], [`tasks`], [`report`] |
//! | dev        | [`testing`] (property-test harness) |

pub mod cfg;
pub mod cli;
pub mod codes;
pub mod embed;
pub mod eval;
pub mod graph;
pub mod lsh;
pub mod params;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod ser;
pub mod sparse;
pub mod tasks;
pub mod testing;
pub mod train;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("config error: {0}")]
    Config(String),
    #[error("shape mismatch: {0}")]
    Shape(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
