//! `hashgnn` — CLI for the embedding-compression GNN stack.
//!
//! Subcommands:
//!   encode      generate a synthetic graph and produce compositional codes
//!   train       end-to-end GNN training — minibatch GraphSAGE (§4) or the
//!               full-batch Table-1 grid (--model node_fb_{gcn,sgc,gin,sage},
//!               link_fb_*); --coder picks the feature front-end (hash /
//!               random / nc / multihash / bloom / poshash); --ckpt-out
//!               saves the trained store
//!   frontier    accuracy-vs-bytes sweep: train the same GNN once per
//!               front-end at matched byte budgets, emit frontier JSON
//!   export      freeze a trained checkpoint + packed codes + edges into a
//!               self-contained serving bundle (--shards K splits it into
//!               K node-range shard files)
//!   infer       answer embed/score/classes queries from a bundle or shard set
//!   serve       serve a bundle or shard set: --oneshot (one JSON request
//!               file), --stdin (persistent NDJSON session), or
//!               --listen <addr> (persistent NDJSON over TCP), with
//!               cross-request batching under a latency budget
//!   merchant    §5.3 merchant-category pipeline (Table 3)
//!   collisions  Figure 3/6 median-vs-zero threshold experiment
//!   memory      Tables 2/4/6 memory accounting
//!   artifacts   list available AOT artifacts / native builds
//!
//! Model-driven commands accept `--backend {auto,native,xla}`: `auto`
//! uses AOT HLO artifacts when the `xla` feature and files are present
//! and otherwise the pure-Rust native backend, so `hashgnn train` runs a
//! full §4 pipeline completely offline. `--threads` bounds the native
//! backend's compute threads without changing any result (bit-identical
//! loss curves across thread counts).
//!
//! Every experiment is seeded and reproducible; benches that regenerate
//! the paper's tables live under `cargo bench` (see DESIGN.md §6).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use hashgnn::cfg::{BackendKind, Coder, CodingCfg, EncodeCfg, GnnKind};
use hashgnn::cli::Args;
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::params::ParamStore;
use hashgnn::report::{self, Table};
use hashgnn::runtime::Engine;
use hashgnn::serve::{
    handle_all_on, load_backend, load_worker_backend, parse_requests, predict_classes_on,
    score_edges_on, server, FaultPlan, RemoteCfg, RemoteRouter, ServeOpts, ServerCfg, Serving,
};
use hashgnn::tasks::nodeclf::{self, Frontend, RunOpts};
use hashgnn::tasks::serve as serve_task;
use hashgnn::tasks::{coding, collisions, frontier, linkpred, memory, merchant, sage, T1Dataset};
use hashgnn::{embed, ser, Error, Result};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".to_string());
    let rest: Vec<String> = argv.into_iter().skip(1).collect();
    let outcome = match cmd.as_str() {
        "encode" => cmd_encode(rest),
        "train" => cmd_train(rest),
        "frontier" => cmd_frontier(rest),
        "export" => cmd_export(rest),
        "infer" => cmd_infer(rest),
        "serve" => cmd_serve(rest),
        "merchant" => cmd_merchant(rest),
        "collisions" => cmd_collisions(rest),
        "memory" => cmd_memory(rest),
        "artifacts" => cmd_artifacts(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = outcome {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "hashgnn — embedding compression with hashing for GNNs (KDD'22 reproduction)\n\n\
         commands:\n\
         \x20 encode      generate graph, run Algorithm 1, save/report codes\n\
         \x20 train       end-to-end GNN training (--model sage_mb |\n\
         \x20             node_fb_{{gcn,sgc,gin,sage}} | link_fb_...);\n\
         \x20             --ckpt-out saves the trained parameters; --coder\n\
         \x20             {{hash,random,nc,multihash,bloom,poshash}} picks the\n\
         \x20             feature front-end\n\
         \x20 frontier    accuracy-vs-bytes sweep over the front-end family\n\
         \x20             (--coders hash,nc,multihash,bloom,poshash --out f.json)\n\
         \x20 export      freeze checkpoint + codes + edges into a serving bundle\n\
         \x20             (--shards K writes K node-range shard files)\n\
         \x20 infer       embed/score/classify from a bundle or shard set\n\
         \x20 serve       --oneshot request file | --stdin persistent NDJSON |\n\
         \x20             --listen <addr> concurrent TCP; batches across requests\n\
         \x20             (and connections) under --max-batch / --max-delay-ms;\n\
         \x20             --shard-worker + --remote run shards as processes\n\
         \x20 merchant    merchant-category identification pipeline (§5.3)\n\
         \x20 collisions  median-vs-zero collision experiment (Fig. 3/6)\n\
         \x20 memory      memory accounting tables (Tables 2/4/6)\n\
         \x20 artifacts   list AOT artifacts / native builds\n\n\
         deployment flow: encode -> train --ckpt-out -> export -> infer/serve\n\n\
         train and merchant take --backend {{auto|native|xla}}: the native\n\
         backend is pure rust (no artifacts needed) and --threads N is\n\
         bit-deterministic across thread counts\n\n\
         run `hashgnn <command> --help` for options\n\n\
         docs: docs/ARCHITECTURE.md (system map), docs/SERVING.md (wire protocol)"
    );
}

/// Parse a comma-separated bundle/shard path list.
fn bundle_paths(s: &str) -> Vec<PathBuf> {
    s.split(',').map(str::trim).filter(|t| !t.is_empty()).map(PathBuf::from).collect()
}

fn cmd_encode(argv: Vec<String>) -> Result<()> {
    let a = Args::new("hashgnn encode", "Algorithm 1 over a synthetic graph")
        .opt("nodes", "10000", "number of nodes")
        .opt("classes", "8", "SBM communities")
        .opt("c", "16", "code cardinality (power of two)")
        .opt("m", "32", "code length")
        .opt("coder", "hash", "coding scheme: hash | random")
        .opt("seed", "7", "rng seed")
        .opt("threads", "0", "encode worker threads (0 = all cores; output is thread-count independent)")
        .opt("block-bits", "0", "projections per pass over A (0 = auto)")
        .opt("out", "", "output file for the bit-packed codes (optional)")
        .parse(argv)?;
    let n = a.get_usize("nodes")?;
    let coding_cfg = CodingCfg::new(a.get_usize("c")?, a.get_usize("m")?)?;
    let coder = Coder::parse(&a.get("coder"))?;
    let seed = a.get_u64("seed")?;
    let plan = EncodeCfg::new(a.get_usize_auto("threads")?, a.get_usize("block-bits")?);
    eprintln!("[encode] generating SBM graph n={n} ...");
    let g = sbm(SbmCfg::new(n, a.get_usize("classes")?, 12.0, 2.0), seed)?;
    eprintln!(
        "[encode] {} threads, {} bits/block",
        plan.resolved_threads(),
        plan.resolved_block_bits(coding_cfg.n_bits())
    );
    let t0 = std::time::Instant::now();
    let table = coding::make_codes_with(&coding::Aux::Graph(&g), coder, coding_cfg, seed, plan)?;
    let dt = t0.elapsed();
    println!(
        "encoded {n} nodes -> {} bits/node ({} KiB total) in {:.2}s ({:.0} nodes/s)",
        coding_cfg.n_bits(),
        table.bits.storage_bytes() / 1024,
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64()
    );
    println!("collisions: {}", table.bits.n_collisions());
    let out = a.get("out");
    if !out.is_empty() {
        table.bits.save(std::path::Path::new(&out))?;
        println!("codes written to {out}");
    }
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let a = Args::new("hashgnn train", "end-to-end GNN training (minibatch §4 or full-batch Table 1)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt(
            "model",
            "sage_mb",
            "sage_mb (minibatch §4) | node_fb_{gcn,sgc,gin,sage} | link_fb_{gcn,sgc,gin,sage} (full-batch grid; one step per epoch)",
        )
        .opt(
            "coder",
            "hash",
            "feature front-end: hash | random | nc | multihash | bloom | poshash",
        )
        .opt("epochs", "5", "training epochs")
        .opt("seed", "7", "rng seed")
        .opt("log-every", "10", "loss log interval (steps)")
        .opt(
            "backend",
            "auto",
            "execution backend: auto (HLO artifacts when available, else native) | native | xla",
        )
        .opt(
            "threads",
            "0",
            "native-backend compute threads (0 = all cores; loss curves are bit-identical across counts)",
        )
        .opt(
            "sample-threads",
            "1",
            "minibatch sampler threads (0 = all cores; per-position seed streams keep batches bit-identical across counts)",
        )
        .opt(
            "prefetch",
            "2",
            "producer→trainer channel depth for pipelined minibatch training (batches buffered ahead)",
        )
        .opt(
            "ckpt-out",
            "",
            "save the trained ParamStore checkpoint here (feeds `hashgnn export`)",
        )
        .parse(argv)?;
    let backend = BackendKind::parse(&a.get("backend"))?;
    let engine =
        Engine::with_backend(a.get("artifacts"), backend, a.get_usize_auto("threads")?)?;
    let model_name = a.get("model");
    if model_name.starts_with("node_fb") || model_name.starts_with("link_fb") {
        return cmd_train_fullbatch(&a, &engine, &model_name);
    }
    if model_name != "sage_mb" {
        return Err(Error::Config(format!(
            "unknown --model '{model_name}' (expected sage_mb | node_fb_<gnn> | link_fb_<gnn>)"
        )));
    }
    let coder_s = a.get("coder");
    let frontend = Frontend::parse_coder(&coder_s).ok_or_else(|| {
        Error::Config(format!(
            "unknown --coder '{coder_s}' (expected hash | random | nc | multihash | bloom | poshash)"
        ))
    })?;
    let coded = frontend.artifact_tag() == "coded";
    let name = format!("sage_mb_{}", frontend.artifact_tag());
    let model = engine.load(&name)?;
    eprintln!("[train] backend: {}", model.backend_name());
    let n = model.manifest.hyper_usize("n")?;
    let k = model.manifest.hyper_usize("n_classes")?;
    let seed = a.get_u64("seed")?;
    eprintln!("[train] generating SBM graph n={n}, {k} classes ...");
    let g = Arc::new(sbm(SbmCfg::new(n, k, 12.0, 2.0), seed)?);
    if model.needs_pos_map() {
        model.bind_pos_map(nodeclf::pos_map_for(&model.manifest, &g)?)?;
    }
    let labels = Arc::new(g.labels().expect("sbm labels").to_vec());
    let make_features = || -> Result<sage::Features> {
        if coded {
            let coding_cfg = CodingCfg::new(
                model.manifest.hyper_usize("c")?,
                model.manifest.hyper_usize("m")?,
            )?;
            let coder = Coder::parse(&a.get("coder"))?;
            let codes = coding::make_codes(&coding::Aux::Graph(&g), coder, coding_cfg, seed)?;
            Ok(sage::Features::Codes(Arc::new(codes)))
        } else {
            Ok(sage::Features::Ids)
        }
    };
    if coded {
        eprintln!("[train] encoding ({}) ...", a.get("coder"));
    }
    let split = hashgnn::graph::split_nodes(n, 0.7, 0.1, seed ^ 0xA5)?;
    let task = sage::SageTask {
        graph: g.clone(),
        labels: labels.clone(),
        features: make_features()?,
        train_nodes: Arc::new(split.train.clone()),
    };
    let epochs = a.get_usize("epochs")?;
    eprintln!("[train] {epochs} epochs ...");
    let cfg = hashgnn::train::PipeCfg {
        sample_threads: a.get_usize_auto("sample-threads")?,
        prefetch: a.get_usize("prefetch")?.max(1),
        pipeline: true,
    };
    let run =
        sage::train_sage_cfg(&model, task, epochs, &split.val, seed, a.get_u64("log-every")?, cfg)?;
    let batcher = sage::SageBatcher::new(
        sage::SageTask {
            graph: g.clone(),
            labels,
            features: make_features()?,
            train_nodes: Arc::new(split.train),
        },
        &model,
        seed,
    )?;
    let test = sage::evaluate(&model, &run.store, &batcher, &split.test, seed ^ 0x99)?;
    println!(
        "val acc {:.4} | test acc {:.4} | final loss {:.4}",
        run.best_val.accuracy,
        test.accuracy,
        run.losses.last().copied().unwrap_or(f32::NAN)
    );
    save_ckpt(&a, &run.store)?;
    Ok(())
}

/// Honor `--ckpt-out` after a training run.
fn save_ckpt(a: &Args, store: &hashgnn::params::ParamStore) -> Result<()> {
    let path = a.get("ckpt-out");
    if !path.is_empty() {
        store.save(std::path::Path::new(&path))?;
        eprintln!("[train] checkpoint written to {path}");
    }
    Ok(())
}

/// `hashgnn train --model node_fb_gin …`: one Table-1 cell on a synthetic
/// OGB analog (n = 1024). Runs on either backend; the native path needs no
/// artifacts and never allocates a dense adjacency.
fn cmd_train_fullbatch(a: &Args, engine: &Engine, model: &str) -> Result<()> {
    // Accept bare "node_fb_gin" or full registry names "node_fb_gin_coded";
    // an explicit front-end suffix wins over --coder.
    let coder_s = a.get("coder");
    let mut frontend = match coder_s.as_str() {
        "rand" | "alone" => Frontend::Rand,
        s => Frontend::parse_coder(s).ok_or_else(|| {
            Error::Config(format!(
                "unknown --coder '{s}' (expected hash | random | nc | multihash | bloom | poshash)"
            ))
        })?,
    };
    for (suffix, fe) in [
        ("_nc", Frontend::Nc),
        ("_multihash", Frontend::MultiHash),
        ("_bloom", Frontend::Bloom),
        ("_poshash", Frontend::PosHash),
    ] {
        if model.ends_with(suffix) {
            frontend = fe;
        }
    }
    if model.ends_with("_coded") && frontend.artifact_tag() != "coded" {
        frontend = Frontend::Hash;
    }
    let base = model
        .trim_end_matches("_coded")
        .trim_end_matches("_nc")
        .trim_end_matches("_multihash")
        .trim_end_matches("_bloom")
        .trim_end_matches("_poshash");
    let (link, gnn_s) = if let Some(r) = base.strip_prefix("node_fb_") {
        (false, r)
    } else if let Some(r) = base.strip_prefix("link_fb_") {
        (true, r)
    } else {
        return Err(Error::Config(format!("malformed full-batch model name '{model}'")));
    };
    let gnn = GnnKind::parse(gnn_s)?;
    let seed = a.get_u64("seed")?;
    let epochs = a.get_usize("epochs")?.max(1);
    let opts = RunOpts { epochs, eval_every: 5.min(epochs), seed };
    let name = format!(
        "{}_fb_{}_{}",
        if link { "link" } else { "node" },
        gnn.as_str(),
        frontend.artifact_tag()
    );
    let model = engine.load(&name)?;
    if link {
        let graph = T1Dataset::Collab.generate(seed)?;
        eprintln!(
            "[train] full-batch {} link prediction ({}, {} front-end), {} epochs ...",
            gnn.as_str(),
            T1Dataset::Collab.name(),
            frontend.name(),
            epochs
        );
        let (out, store) = linkpred::run_fullbatch_model(&model, frontend, &graph, 50, opts)?;
        println!(
            "val hits@50 {:.4} | test hits@50 {:.4} | final loss {:.4}",
            out.val_hits, out.test_hits, out.final_loss
        );
        save_ckpt(a, &store)?;
    } else {
        let graph = T1Dataset::Arxiv.generate(seed)?;
        eprintln!(
            "[train] full-batch {} node classification ({}, {} front-end), {} epochs ...",
            gnn.as_str(),
            T1Dataset::Arxiv.name(),
            frontend.name(),
            epochs
        );
        let (out, store) = nodeclf::run_fullbatch_model(&model, frontend, &graph, opts)?;
        println!(
            "val acc {:.4} | test acc {:.4} | final loss {:.4}",
            out.val, out.test, out.final_loss
        );
        save_ckpt(a, &store)?;
    }
    Ok(())
}

/// `hashgnn frontier`: the accuracy-vs-bytes sweep over the feature
/// front-end family — the paper's LSH coding, the NC baseline, and the
/// three hash-embedding competitors, all sized bytes-fair against the
/// §3.2 coded front-end budget.
fn cmd_frontier(argv: Vec<String>) -> Result<()> {
    let a = Args::new(
        "hashgnn frontier",
        "accuracy-vs-bytes sweep over the feature front-end family",
    )
    .opt(
        "coders",
        "hash,nc,multihash,bloom,poshash",
        "comma-separated front-ends to sweep (hash | nc | random | multihash | bloom | poshash)",
    )
    .opt("gnn", "gin", "full-batch GNN architecture: gcn | sgc | gin | sage")
    .opt("dataset", "arxiv", "Table-1 node-classification analog: arxiv | mag | products")
    .opt("epochs", "60", "training epochs per coder")
    .opt("eval-every", "5", "validation interval (epochs)")
    .opt("seed", "7", "rng seed (graph, split, init and hash streams)")
    .opt("threads", "0", "native-backend compute threads (0 = all cores; results are thread-count independent)")
    .opt("out", "", "write the frontier JSON artifact here (optional)")
    .flag(
        "quick",
        "CI smoke: two coders (nc, bloom) for 10 epochs — overrides --coders / --epochs / --eval-every",
    )
    .parse(argv)?;
    let quick = a.get_bool("quick");
    let mut opts =
        if quick { frontier::FrontierOpts::quick() } else { frontier::FrontierOpts::default() };
    let seed = a.get_u64("seed")?;
    if quick {
        opts.run.seed = seed;
    } else {
        opts.coders = frontier::parse_coders(&a.get("coders"))?;
        let epochs = a.get_usize("epochs")?.max(1);
        opts.run = RunOpts { epochs, eval_every: a.get_usize("eval-every")?.max(1).min(epochs), seed };
    }
    opts.gnn = GnnKind::parse(&a.get("gnn"))?;
    opts.dataset = match a.get("dataset").as_str() {
        "arxiv" => T1Dataset::Arxiv,
        "mag" => T1Dataset::Mag,
        "products" => T1Dataset::Products,
        other => {
            return Err(Error::Config(format!(
                "unknown --dataset '{other}' (expected arxiv | mag | products)"
            )))
        }
    };
    opts.threads = a.get_usize_auto("threads")?;
    eprintln!(
        "[frontier] {} on {}: {} coder(s), {} epochs each ...",
        opts.gnn.as_str(),
        opts.dataset.name(),
        opts.coders.len(),
        opts.run.epochs
    );
    let rows = frontier::run_frontier(&opts)?;
    for r in &rows {
        println!(
            "{:>9} coder: {:>9} front-end bytes | test acc {:.4} | val {:.4} | loss {:.4}",
            r.coder, r.bytes, r.acc, r.val, r.loss
        );
    }
    let json = frontier::rows_to_json(&rows, &opts);
    let out = a.get("out");
    if out.is_empty() {
        println!("{}", ser::to_string_compact(&json));
    } else {
        std::fs::write(&out, ser::to_string_pretty(&json))?;
        eprintln!("[frontier] JSON written to {out}");
    }
    Ok(())
}

/// Parse `"0,1,2"` into node ids.
fn parse_ids(s: &str) -> Result<Vec<u32>> {
    s.split(',')
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.trim()
                .parse::<u32>()
                .map_err(|_| Error::Config(format!("bad node id '{t}' (expected e.g. 0,1,2)")))
        })
        .collect()
}

/// Parse `"0-1,2-3"` into (u, v) edges.
fn parse_edges(s: &str) -> Result<Vec<(u32, u32)>> {
    s.split(',')
        .filter(|t| !t.is_empty())
        .map(|t| {
            let (u, v) = t
                .trim()
                .split_once('-')
                .ok_or_else(|| Error::Config(format!("bad edge '{t}' (expected e.g. 0-1,2-3)")))?;
            Ok((
                u.parse::<u32>()
                    .map_err(|_| Error::Config(format!("bad edge endpoint '{u}'")))?,
                v.parse::<u32>()
                    .map_err(|_| Error::Config(format!("bad edge endpoint '{v}'")))?,
            ))
        })
        .collect()
}

fn cmd_export(argv: Vec<String>) -> Result<()> {
    let a = Args::new("hashgnn export", "freeze a trained model into a serving bundle")
        .req("checkpoint", "trained ParamStore checkpoint (`hashgnn train --ckpt-out`)")
        .req("out", "output bundle path")
        .opt("model", "sage_mb_coded", "model/artifact name the checkpoint was trained for")
        .opt("artifacts", "artifacts", "artifacts directory (exported manifests used when present)")
        .opt("coder", "hash", "coding scheme when codes are regenerated: hash | random")
        .opt(
            "codes",
            "",
            "pre-encoded bit-packed code file (`hashgnn encode --out`); default: regenerate \
             via Algorithm 1 from the training graph",
        )
        .opt("seed", "7", "the training run's seed (graph, split and codes derive from it)")
        .opt(
            "shards",
            "1",
            "split the export into K contiguous node-range shard files \
             (<out>.shard-<i>-of-<K>, served together by the shard router)",
        )
        .opt(
            "quant",
            "f32",
            "parameter encoding: f32 (exact) | int8 (per-row quantization of rank-2 \
             tensors, ~4x smaller params, dequantized once at load)",
        )
        .flag(
            "legacy-v1",
            "write the superseded HGNB0001 envelope instead of the v2 section table \
             (back-compat fixtures / before-after benches; f32 only)",
        )
        .parse(argv)?;
    // The bundle is a native-serving artifact; the native backend loads
    // (or synthesizes) the manifest without requiring HLO files.
    let engine = Engine::with_backend(a.get("artifacts"), BackendKind::Native, 0)?;
    let model = engine.load(&a.get("model"))?;
    let store = ParamStore::load(Path::new(&a.get("checkpoint")))?;
    let codes = a.get("codes");
    let opts = serve_task::ExportOpts {
        coder: Coder::parse(&a.get("coder"))?,
        codes_file: if codes.is_empty() { None } else { Some(codes.into()) },
        seed: a.get_u64("seed")?,
        quant: hashgnn::serve::Quant::parse(&a.get("quant"))?,
        legacy_v1: a.get_bool("legacy-v1"),
    };
    let out = a.get("out");
    let shards = a.get_usize("shards")?;
    eprintln!("[export] assembling bundle for '{}' ...", model.manifest.name);
    if shards <= 1 {
        let bundle =
            serve_task::export_bundle_to(&model.manifest, &store, &opts, Path::new(&out))?;
        println!(
            "bundle '{}' written to {out}: {} nodes, {} edges, {} KiB params, {} KiB packed codes",
            bundle.manifest.name,
            bundle.n_nodes,
            bundle.edges.len(),
            bundle.param_bytes() / 1024,
            bundle.code_bytes() / 1024
        );
    } else {
        let written = serve_task::export_sharded_to(
            &model.manifest,
            &store,
            &opts,
            shards,
            Path::new(&out),
        )?;
        for (path, shard) in &written {
            let info = shard.shard.as_ref().expect("sharded export tags every file");
            println!(
                "shard {}/{} [{}, {}) written to {}: {} edges, {} KiB params, {} KiB packed codes",
                info.index,
                info.count,
                info.lo,
                info.hi,
                path.display(),
                shard.edges.len(),
                shard.param_bytes() / 1024,
                shard.code_bytes() / 1024
            );
        }
        let all: Vec<String> =
            written.iter().map(|(p, _)| p.display().to_string()).collect();
        println!("serve the set with: hashgnn serve --bundle {}", all.join(","));
    }
    Ok(())
}

fn cmd_infer(argv: Vec<String>) -> Result<()> {
    let a = Args::new("hashgnn infer", "answer embed/score/classes queries from a bundle")
        .req(
            "bundle",
            "serving bundle, or comma-separated shard set (`hashgnn export [--shards K]`)",
        )
        .opt("embed", "", "comma-separated node ids to embed (e.g. 0,1,2)")
        .opt("score", "", "dash-pair edges to score (e.g. 0-1,2-3)")
        .opt("classes", "", "comma-separated node ids to classify")
        .opt("threads", "0", "compute threads (0 = all cores; never changes any served bit)")
        .opt("cache", "4096", "embedding-cache capacity in entries (0 disables)")
        .opt("seed", "7", "fan-out sampling seed (minibatch models)")
        .flag(
            "no-fanout",
            "walk shard sub-requests sequentially instead of in parallel (bytes are \
             identical either way; only latency changes)",
        )
        .flag(
            "mmap",
            "map bundle file(s) into memory instead of heap-reading them (needs a \
             build with --features mmap; served bytes are identical)",
        )
        .parse(argv)?;
    let paths = bundle_paths(&a.get("bundle"));
    let mut backend = load_backend(
        &paths,
        ServeOpts {
            threads: a.get_usize_auto("threads")?,
            cache_capacity: a.get_usize("cache")?,
            seed: a.get_u64("seed")?,
            fanout: !a.get_bool("no-fanout"),
            mmap: a.get_bool("mmap"),
        },
    )?;
    let session = backend.as_mut();
    eprintln!(
        "[infer] {} file(s): {} nodes, embedding dim {}",
        paths.len(),
        session.n_nodes(),
        session.embed_dim()
    );
    let mut did_anything = false;
    let embed_q = a.get("embed");
    if !embed_q.is_empty() {
        let ids = parse_ids(&embed_q)?;
        let emb = session.embed_nodes(&ids)?;
        let d = session.embed_dim();
        for (i, &id) in ids.iter().enumerate() {
            let row = &emb[i * d..(i + 1) * d];
            let norm = row.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            let head: Vec<String> = row.iter().take(6).map(|x| format!("{x:.4}")).collect();
            println!(
                "embed {id}: [{}{}] |h| = {norm:.4}",
                head.join(", "),
                if d > 6 { ", ..." } else { "" }
            );
        }
        did_anything = true;
    }
    let score_q = a.get("score");
    if !score_q.is_empty() {
        let edges = parse_edges(&score_q)?;
        let scores = score_edges_on(session, &edges)?;
        for (&(u, v), &s) in edges.iter().zip(&scores) {
            println!("score {u}-{v}: {s:.4}");
        }
        did_anything = true;
    }
    let classes_q = a.get("classes");
    if !classes_q.is_empty() {
        let ids = parse_ids(&classes_q)?;
        let (_logits, argmax) = predict_classes_on(session, &ids)?;
        for (&id, &c) in ids.iter().zip(&argmax) {
            println!("class {id}: {c}");
        }
        did_anything = true;
    }
    if !did_anything {
        return Err(Error::Config(
            "nothing to do — pass --embed, --score and/or --classes".into(),
        ));
    }
    eprintln!("[infer] cache: {}", ser::to_string_compact(&session.stats_json()));
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let a = Args::new(
        "hashgnn serve",
        "serve a bundle, shard set, or remote worker fleet: one-shot request file, \
         persistent NDJSON, or concurrent TCP",
    )
    .opt(
        "bundle",
        "",
        "serving bundle, or comma-separated shard set (`hashgnn export [--shards K]`)",
    )
    .opt(
        "remote",
        "",
        "comma-separated shard-worker addresses to route to instead of --bundle \
         (each runs `serve --shard-worker --listen <addr>`)",
    )
    .flag("oneshot", "process one --requests file and exit")
    .flag("stdin", "persistent NDJSON session: one request per stdin line, one response per stdout line")
    .opt(
        "listen",
        "",
        "concurrent NDJSON server on this TCP address (e.g. 127.0.0.1:7433, or :0 with \
         --port-file); all connections share one batcher and one warm backend",
    )
    .flag(
        "shard-worker",
        "with --listen: serve ONE shard file as a worker process — ids outside the \
         owned range are rejected per line; `stats` advertises the range",
    )
    .opt(
        "requests",
        "",
        "JSON request file for --oneshot: {\"requests\": [{\"op\": \"embed\", \"nodes\": [0, 1]}, \
         {\"op\": \"score\", \"edges\": [[0, 1]]}, {\"op\": \"classes\", \"nodes\": [2]}]}",
    )
    .opt(
        "max-batch",
        "256",
        "persistent modes: flush once this many distinct node ids are pending",
    )
    .opt(
        "max-delay-ms",
        "5",
        "persistent modes: flush once the oldest pending request has waited this long",
    )
    .opt(
        "deadline-ms",
        "none",
        "persistent modes: shed requests that waited longer than this with \
         {\"error\": \"deadline\"} in position (none/0 = no deadline)",
    )
    .opt(
        "queue-cap",
        "1024",
        "persistent modes: pending-request bound; overflow sheds {\"error\": \"overloaded\"} \
         in position",
    )
    .opt(
        "max-line-bytes",
        "1048576",
        "longest accepted input line; longer lines answer {\"error\": \"line_too_long\"} \
         in position without being buffered",
    )
    .opt(
        "max-conns",
        "0",
        "TCP mode: concurrent-connection cap (0 = unlimited); excess connections get one \
         {\"error\": \"overloaded\"} line and are closed",
    )
    .opt(
        "port-file",
        "",
        "TCP mode: write the bound address to this file after bind (use with --listen \
         127.0.0.1:0 so tests/scripts learn the kernel-assigned port)",
    )
    .opt(
        "fault",
        "",
        "deterministic fault injection for degradation tests: comma-separated \
         drop:N | delay:N:MS | truncate:N | corrupt:N | kill:K (1-based response \
         ordinals; overrides HASHGNN_FAULT; TCP mode only)",
    )
    .opt("connect-timeout-ms", "1000", "--remote: TCP dial timeout per worker")
    .opt("request-timeout-ms", "5000", "--remote: per-request read/write timeout")
    .opt("retries", "2", "--remote: retry budget per request (attempts = retries + 1)")
    .opt("backoff-ms", "50", "--remote: first retry sleep, doubling per attempt")
    .opt(
        "health-every-ms",
        "1000",
        "--remote: minimum interval between health probes of a down worker (0 = probe \
         on every routing decision)",
    )
    .opt("threads", "0", "compute threads (0 = all cores)")
    .opt("cache", "4096", "embedding-cache capacity in entries (0 disables)")
    .opt("seed", "7", "fan-out sampling seed (minibatch models)")
    .flag(
        "no-fanout",
        "dispatch shard sub-requests sequentially instead of in parallel (local router) \
         or unpipelined (--remote); served bytes are identical either way",
    )
    .flag(
        "mmap",
        "map bundle file(s) into memory instead of heap-reading them (needs a build \
         with --features mmap; served bytes are identical)",
    )
    .parse(argv)?;
    let listen = a.get("listen");
    let n_modes = [a.get_bool("oneshot"), a.get_bool("stdin"), !listen.is_empty()]
        .iter()
        .filter(|&&m| m)
        .count();
    if n_modes != 1 {
        return Err(Error::Config(
            "pick exactly one serving mode: --oneshot (one request file), --stdin \
             (persistent NDJSON session on stdio), or --listen <addr> (concurrent NDJSON \
             over TCP) — see docs/SERVING.md for the protocol"
                .into(),
        ));
    }
    let bundle = a.get("bundle");
    let remote = a.get("remote");
    if bundle.is_empty() == remote.is_empty() {
        return Err(Error::Config(
            "pass exactly one of --bundle <files> (serve locally) or --remote <addrs> \
             (route to shard workers)"
                .into(),
        ));
    }
    if a.get_bool("shard-worker") && (listen.is_empty() || bundle.is_empty()) {
        return Err(Error::Config(
            "--shard-worker needs --listen <addr> and --bundle <shard file>: a worker is \
             one shard process behind a socket"
                .into(),
        ));
    }
    let mut backend: Box<dyn Serving> = if !remote.is_empty() {
        let addrs: Vec<String> = remote
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        let rcfg = RemoteCfg {
            connect_timeout: Duration::from_millis(a.get_u64("connect-timeout-ms")?),
            request_timeout: Duration::from_millis(a.get_u64("request-timeout-ms")?),
            retries: a.get_u64("retries")? as u32,
            backoff: Duration::from_millis(a.get_u64("backoff-ms")?),
            health_every: Duration::from_millis(a.get_u64("health-every-ms")?),
            max_line_bytes: a.get_usize("max-line-bytes")?,
            fanout: !a.get_bool("no-fanout"),
        };
        let router = RemoteRouter::connect(&addrs, rcfg)?;
        eprintln!(
            "[serve] routing {} nodes across {} worker(s)",
            router.n_nodes(),
            addrs.len()
        );
        Box::new(router)
    } else {
        let paths = bundle_paths(&bundle);
        let opts = ServeOpts {
            threads: a.get_usize_auto("threads")?,
            cache_capacity: a.get_usize("cache")?,
            seed: a.get_u64("seed")?,
            fanout: !a.get_bool("no-fanout"),
            mmap: a.get_bool("mmap"),
        };
        if a.get_bool("shard-worker") {
            load_worker_backend(&paths, opts)?
        } else {
            load_backend(&paths, opts)?
        }
    };
    if a.get_bool("oneshot") {
        let req_path = a.get("requests");
        if req_path.is_empty() {
            return Err(Error::Config(
                "--requests <file.json> is required with --oneshot".into(),
            ));
        }
        let reqs = parse_requests(&ser::from_file(Path::new(&req_path))?)?;
        eprintln!("[serve] oneshot: {} request(s)", reqs.len());
        let out = handle_all_on(backend.as_mut(), &reqs)?;
        println!("{}", ser::to_string_pretty(&out));
        return Ok(());
    }
    let deadline = match a.get("deadline-ms").as_str() {
        "" | "none" | "0" => None,
        s => Some(Duration::from_millis(s.parse::<u64>().map_err(|_| {
            Error::Config(format!(
                "--deadline-ms: '{s}' is not a millisecond count (or 'none')"
            ))
        })?)),
    };
    let cfg = ServerCfg {
        max_batch: a.get_usize("max-batch")?,
        max_delay: Duration::from_millis(a.get_u64("max-delay-ms")?),
        deadline,
        queue_cap: a.get_usize("queue-cap")?,
        max_line_bytes: a.get_usize("max-line-bytes")?,
    };
    if a.get_bool("stdin") {
        eprintln!(
            "[serve] persistent NDJSON session on stdin/stdout (max-batch {}, max-delay {:?})",
            cfg.max_batch, cfg.max_delay
        );
        let stats = server::serve_stdin(backend.as_mut(), &cfg)?;
        eprintln!("[serve] session ended: {}", stats.summary());
    } else {
        let fault_spec = a.get("fault");
        let fault = if fault_spec.is_empty() {
            FaultPlan::from_env()?
        } else {
            Some(FaultPlan::parse(&fault_spec)?)
        };
        let max_conns = a.get_usize("max-conns")?;
        let listener = std::net::TcpListener::bind(&listen)?;
        let local = listener.local_addr()?;
        let port_file = a.get("port-file");
        if !port_file.is_empty() {
            std::fs::write(&port_file, local.to_string())?;
        }
        eprintln!(
            "[serve] listening on {local} ({}max-batch {}, max-delay {:?}, queue-cap {}, \
             max-conns {}{})",
            if a.get_bool("shard-worker") { "shard worker, " } else { "" },
            cfg.max_batch,
            cfg.max_delay,
            cfg.queue_cap,
            max_conns,
            if fault.is_some() { ", FAULT INJECTION ACTIVE" } else { "" },
        );
        let stats =
            server::serve_concurrent(listener, backend.as_mut(), &cfg, max_conns, fault)?;
        eprintln!("[serve] done: {}", stats.summary());
    }
    eprintln!("[serve] cache: {}", ser::to_string_compact(&backend.stats_json()));
    Ok(())
}

fn cmd_merchant(argv: Vec<String>) -> Result<()> {
    let a = Args::new("hashgnn merchant", "merchant-category identification (§5.3)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("coder", "hash", "coding scheme: hash | random")
        .opt("epochs", "3", "training epochs")
        .opt("seed", "11", "rng seed")
        .opt("backend", "auto", "execution backend: auto | native | xla")
        .opt("threads", "0", "native-backend compute threads (0 = all cores)")
        .parse(argv)?;
    let engine = Engine::with_backend(
        a.get("artifacts"),
        BackendKind::parse(&a.get("backend"))?,
        a.get_usize_auto("threads")?,
    )?;
    let model = engine.load("merchant")?;
    eprintln!("[merchant] backend: {}", model.backend_name());
    let seed = a.get_u64("seed")?;
    eprintln!("[merchant] building transaction graph ...");
    let bip = merchant::build_graph(&model, seed)?;
    let coder = Coder::parse(&a.get("coder"))?;
    let out = merchant::run(&engine, &bip, coder, a.get_usize("epochs")?, seed)?;
    println!(
        "{}: acc {:.4} | hit@5 {:.4} | hit@10 {:.4} | hit@20 {:.4}",
        coder.as_str(),
        out.metrics.accuracy,
        out.metrics.hit5,
        out.metrics.hit10,
        out.metrics.hit20
    );
    Ok(())
}

fn cmd_collisions(argv: Vec<String>) -> Result<()> {
    let a = Args::new("hashgnn collisions", "Fig. 3/6 median-vs-zero thresholds")
        .opt("entities", "20000", "number of entities")
        .opt("bits", "24", "code bits")
        .opt("trials", "20", "number of trials")
        .opt("seed", "3", "rng seed")
        .parse(argv)?;
    let n = a.get_usize("entities")?;
    let set = embed::gaussian_mixture(n, 128, 8, 0.25, a.get_u64("seed")?);
    let r =
        collisions::run("metapath2vec*", &set, a.get_usize("bits")?, a.get_usize("trials")?, 100);
    println!("{}", report::histogram("median threshold", &r.median, 8));
    println!("{}", report::histogram("zero threshold", &r.zero, 8));
    println!("avg collisions: median {:.1} | zero {:.1}", r.median_avg(), r.zero_avg());
    Ok(())
}

fn cmd_memory(argv: Vec<String>) -> Result<()> {
    let _a = Args::new("hashgnn memory", "Tables 2/4/6 memory accounting").parse(argv)?;
    let coding_cfg = CodingCfg::new(256, 16)?;
    let rows = memory::table2(1_871_031, 64, coding_cfg, 512, 512, (1.35 * memory::MIB) as usize);
    let mut t = Table::new(
        "Table 2 — memory cost (MiB) on ogbn-products (paper scale)",
        &[
            "Method", "CPU code", "CPU dec", "CPU tot", "GPU model", "GPU gnn", "GPU tot",
            "GPU ratio", "Total", "Ratio",
        ],
    );
    for r in rows {
        t.row(vec![
            r.method.clone(),
            format!("{:.2}", r.cpu_code),
            format!("{:.2}", r.cpu_decoder),
            format!("{:.2}", r.cpu_total),
            format!("{:.2}", r.gpu_model),
            format!("{:.2}", r.gpu_gnn),
            format!("{:.2}", r.gpu_total),
            format!("{:.2}", r.gpu_ratio),
            format!("{:.2}", r.total),
            format!("{:.2}", r.total_ratio),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_artifacts(argv: Vec<String>) -> Result<()> {
    let a = Args::new("hashgnn artifacts", "list AOT artifacts and native builds")
        .opt("artifacts", "artifacts", "artifacts directory")
        .parse(argv)?;
    let idx = std::path::Path::new(&a.get("artifacts")).join("index.json");
    match hashgnn::ser::from_file(&idx) {
        Ok(v) => {
            for name in v.get("artifacts")?.as_arr()? {
                println!("{}", name.as_str()?);
            }
        }
        Err(_) => {
            eprintln!(
                "(no AOT index at {}; the native backend synthesizes these builds)",
                idx.display()
            );
            for name in hashgnn::runtime::native::spec::builtin_names() {
                println!("{name} (native)");
            }
        }
    }
    Ok(())
}
