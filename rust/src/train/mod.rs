//! Training coordinator: drives a train-step executable with a pipelined
//! batch producer. Backend-agnostic — the [`Model`]'s executables may be
//! AOT-compiled HLO or the pure-Rust native engine.
//!
//! The producer (neighbor sampling, code gathering, negative-edge drawing —
//! all pure rust) runs on its own thread and feeds a bounded channel; the
//! consumer thread keeps the executable busy. This is the L3 concurrency
//! story: batch preparation overlaps step execution, the paper's
//! "scalable training on industrial graphs" requirement (Section 4 /
//! Figure 4 pipeline).
//!
//! **Determinism:** sources seed per step index, so the batch for step
//! `s` is the same whether produced ahead (pipelined) or on demand; the
//! consumer applies steps strictly in channel order (a single-producer
//! `sync_channel` preserves send order), so pipelined and serial runs
//! produce bit-identical loss curves — asserted by the test suite on the
//! native backend.

use std::sync::mpsc;

use crate::params::ParamStore;
use crate::runtime::{Model, Tensor};
use crate::Result;

/// Anything that can produce train-step batch tensors. `step` is the
/// global step index (sources use it to seed per-step sampling so runs
/// stay deterministic regardless of pipelining).
pub trait BatchSource: Send {
    fn next_batch(&mut self, step: u64) -> Vec<Tensor>;
}

/// Blanket impl so closures can be sources.
impl<F: FnMut(u64) -> Vec<Tensor> + Send> BatchSource for F {
    fn next_batch(&mut self, step: u64) -> Vec<Tensor> {
        self(step)
    }
}

/// Per-run training log.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
}

impl TrainLog {
    /// Mean loss of the last `k` steps (loss-curve smoothing for reports).
    pub fn tail_mean(&self, k: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let k = k.min(self.losses.len()).max(1);
        let tail = &self.losses[self.losses.len() - k..];
        tail.iter().sum::<f32>() / k as f32
    }
}

/// Training-loop options.
#[derive(Clone, Copy, Debug)]
pub struct TrainOpts {
    pub n_steps: u64,
    /// Overlap batch production with execution (bounded producer channel).
    pub pipeline: bool,
    /// Print a progress line every `log_every` steps (0 = silent).
    pub log_every: u64,
    /// Producer channel depth when pipelined: the producer runs at most
    /// this many batches ahead of the consumer. Depth never changes the
    /// math (batches are seeded by step index and applied in send order),
    /// only how much sampling latency the pipeline can hide.
    pub prefetch: usize,
}

impl TrainOpts {
    pub fn new(n_steps: u64) -> Self {
        Self { n_steps, pipeline: true, log_every: 0, prefetch: 2 }
    }

    pub fn silent(n_steps: u64) -> Self {
        Self::new(n_steps)
    }
}

/// Pipeline knobs the CLI exposes on `hashgnn train` and the task-level
/// drivers (`train_sage_cfg`, `train_sage_link_cfg`) thread through to
/// [`TrainOpts`] and the batchers. None of these change a single trained
/// bit — they only move where time is spent.
#[derive(Clone, Copy, Debug)]
pub struct PipeCfg {
    /// Worker threads for deterministic neighbor sampling / negative
    /// drawing inside the batch producer (1 = sequential reference).
    pub sample_threads: usize,
    /// Producer channel depth (see [`TrainOpts::prefetch`]).
    pub prefetch: usize,
    /// Overlap batch production with step execution.
    pub pipeline: bool,
}

impl Default for PipeCfg {
    fn default() -> Self {
        Self { sample_threads: 1, prefetch: 2, pipeline: true }
    }
}

/// Run `opts.n_steps` train steps of `model`, mutating `store` in place.
pub fn train(
    model: &Model,
    store: &mut ParamStore,
    source: impl BatchSource + 'static,
    opts: TrainOpts,
) -> Result<TrainLog> {
    if opts.pipeline {
        train_pipelined(model, store, source, opts)
    } else {
        train_serial(model, store, source, opts)
    }
}

fn train_serial(
    model: &Model,
    store: &mut ParamStore,
    mut source: impl BatchSource,
    opts: TrainOpts,
) -> Result<TrainLog> {
    let mut log = TrainLog::default();
    for step in 0..opts.n_steps {
        let batch = source.next_batch(step);
        let loss = run_step(model, store, &batch)?;
        maybe_log(step, loss, opts.log_every);
        log.losses.push(loss);
    }
    Ok(log)
}

fn train_pipelined(
    model: &Model,
    store: &mut ParamStore,
    mut source: impl BatchSource + 'static,
    opts: TrainOpts,
) -> Result<TrainLog> {
    let n_steps = opts.n_steps;
    // Bounded channel: the producer stays at most `prefetch` batches ahead,
    // so memory is bounded and the consumer never waits on a cold producer.
    let (tx, rx) = mpsc::sync_channel::<(u64, Vec<Tensor>)>(opts.prefetch.max(1));
    let producer = std::thread::spawn(move || {
        for step in 0..n_steps {
            let batch = source.next_batch(step);
            if tx.send((step, batch)).is_err() {
                return source; // consumer dropped (error path)
            }
        }
        source
    });
    let mut log = TrainLog::default();
    let mut result = Ok(());
    for (step, batch) in rx {
        match run_step(model, store, &batch) {
            Ok(loss) => {
                maybe_log(step, loss, opts.log_every);
                log.losses.push(loss);
            }
            Err(e) => {
                result = Err(e);
                break;
            }
        }
    }
    producer.join().map_err(|_| crate::Error::Runtime("batch producer panicked".into()))?;
    result.map(|_| log)
}

/// One synchronous train step.
pub fn run_step(model: &Model, store: &mut ParamStore, batch: &[Tensor]) -> Result<f32> {
    validate_batch(model, batch)?;
    let inputs = store.train_inputs(batch);
    let outputs = model.train.run(&inputs)?;
    store.absorb(outputs)
}

/// Run the predict executable over one batch.
pub fn predict(model: &Model, store: &ParamStore, batch: &[Tensor]) -> Result<Tensor> {
    let inputs = store.pred_inputs(batch);
    let mut out = model.pred.run(&inputs)?;
    if out.len() != 1 {
        return Err(crate::Error::Runtime(format!(
            "predict returned {} tensors, expected 1",
            out.len()
        )));
    }
    Ok(out.pop().expect("len checked"))
}

fn validate_batch(model: &Model, batch: &[Tensor]) -> Result<()> {
    let specs = &model.manifest.train_inputs;
    if batch.len() != specs.len() {
        return Err(crate::Error::Shape(format!(
            "batch has {} tensors, manifest expects {}",
            batch.len(),
            specs.len()
        )));
    }
    for (t, s) in batch.iter().zip(specs) {
        if t.shape() != s.shape.as_slice() {
            return Err(crate::Error::Shape(format!(
                "input '{}': got shape {:?}, manifest says {:?}",
                s.name,
                t.shape(),
                s.shape
            )));
        }
    }
    Ok(())
}

fn maybe_log(step: u64, loss: f32, log_every: u64) {
    // Step 0's loss is pre-training noise; only print it when the user
    // asked for every step (`log_every == 1`).
    if log_every > 0 && step % log_every == 0 && (step > 0 || log_every == 1) {
        eprintln!("[train] step {step:>6}  loss {loss:.5}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_mean_behaviour() {
        let log = TrainLog { losses: vec![4.0, 3.0, 2.0, 1.0] };
        assert_eq!(log.tail_mean(2), 1.5);
        assert_eq!(log.tail_mean(100), 2.5);
        assert!(TrainLog::default().tail_mean(3).is_nan());
    }

    #[test]
    fn closure_is_a_batch_source() {
        let mut calls = 0u64;
        let _ = &calls;
        let mut src = move |step: u64| {
            calls += 1;
            vec![Tensor::scalar_f32(step as f32)]
        };
        let b = src.next_batch(7);
        assert_eq!(b[0].scalar().unwrap(), 7.0);
    }
}
