//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so this module is the project's RNG
//! substrate: a [`SplitMix64`] seeder, a [`Xoshiro256pp`] main generator,
//! and the sampling routines the rest of the crate needs (uniforms, unit
//! normals via Ziggurat-free Box–Muller, Zipf, permutations, reservoir-free
//! index sampling). Everything is explicitly seeded; two runs with the same
//! seed produce byte-identical streams on every platform.

mod zipf;

pub use zipf::Zipf;

/// The SplitMix64 finalizer as a standalone mixing function: a bijective
/// avalanche permutation of `u64`. Used for seeding, stream derivation
/// ([`derive_stream_seed`]) and cheap content hashing
/// (`BitMatrix::n_collisions`).
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive the seed of logical stream `stream` under a root seed.
///
/// Deterministic stream splitting for parallel work: two distinct
/// `(root, stream)` pairs land in decorrelated states (Weyl increment on
/// the root, a second odd multiplier on the stream index, then the
/// SplitMix64 avalanche). The LSH engine gives every output *bit* its own
/// stream, which is what makes encode output independent of block size,
/// thread count and scheduling — see [`crate::lsh`].
#[inline]
pub fn derive_stream_seed(root: u64, stream: u64) -> u64 {
    mix64(
        root.wrapping_add(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(stream.wrapping_mul(0xD1B5_4A32_D192_ED03)),
    )
}

/// SplitMix64 — used to expand a single `u64` seed into generator state.
///
/// Reference: Steele, Lea & Flood, "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014). Passes BigCrush when used as a stream.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        mix64(self.state)
    }
}

/// Xoshiro256++ — the crate's main PRNG.
///
/// Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
/// generators" (2019). Period 2^256 − 1; `jump()` gives 2^128 disjoint
/// subsequences for parallel workers.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as recommended by the authors.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Generator for logical stream `stream` under `seed` (see
    /// [`derive_stream_seed`]). Unlike [`Self::split`], which advances a
    /// shared generator, this is stateless: any worker can construct the
    /// generator for any stream index without coordination.
    pub fn seed_for_stream(seed: u64, stream: u64) -> Self {
        Self::seed_from_u64(derive_stream_seed(seed, stream))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Jump ahead 2^128 steps (for handing disjoint streams to workers).
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180E_C6D3_3CFD_0ABA,
            0xD5A6_1266_F0C9_392C,
            0xA958_9759_90E0_5616,
            0x3982_3DC4_5812_9EAC,
        ];
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
    }

    /// A fresh generator 2^128 steps ahead; `self` advances past the jump.
    pub fn split(&mut self) -> Self {
        let child = self.clone();
        self.jump();
        child
    }
}

/// Sampling adapters over any `u64` source.
pub trait Rng {
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift
    /// (unbiased, rejection on the low word).
    #[inline]
    fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize index in `[0, n)`.
    #[inline]
    fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached second value omitted to stay
    /// stateless; the cost is one extra `cos`, irrelevant off the hot path).
    #[inline]
    fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    #[inline]
    fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm);
    /// order is randomized. Panics if `k > n`.
    fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        self.shuffle(&mut out);
        out
    }

    /// Fill a slice with standard normals scaled by `std` (f32).
    fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_ms(mean as f64, std as f64) as f32;
        }
    }

    /// Fill a slice with uniforms in `[lo, hi)` (f32).
    fn fill_uniform_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.range_f64(lo as f64, hi as f64) as f32;
        }
    }
}

impl Rng for Xoshiro256pp {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        Xoshiro256pp::next_u64(self)
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        SplitMix64::next_u64(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference stream for seed 1234567 (computed from the canonical
        // C implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism across instances.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_seed_sensitive() {
        let mut r1 = Xoshiro256pp::seed_from_u64(42);
        let mut r2 = Xoshiro256pp::seed_from_u64(42);
        let mut r3 = Xoshiro256pp::seed_from_u64(43);
        let s1: Vec<u64> = (0..8).map(|_| r1.next_u64()).collect();
        let s2: Vec<u64> = (0..8).map(|_| r2.next_u64()).collect();
        let s3: Vec<u64> = (0..8).map(|_| r3.next_u64()).collect();
        assert_eq!(s1, s2);
        assert_ne!(s1, s3);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should be hit");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        for _ in 0..50 {
            let n = 1 + r.index(50);
            let k = r.index(n + 1);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "indices must be distinct");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn stream_seeds_deterministic_and_distinct() {
        assert_eq!(derive_stream_seed(7, 3), derive_stream_seed(7, 3));
        assert_ne!(derive_stream_seed(7, 3), derive_stream_seed(7, 4));
        assert_ne!(derive_stream_seed(7, 3), derive_stream_seed(8, 3));
        // No collisions over a large stream fan-out (mix64 is bijective, so
        // collisions would require distinct pre-mix states colliding).
        let seeds: std::collections::HashSet<u64> =
            (0..10_000).map(|s| derive_stream_seed(42, s)).collect();
        assert_eq!(seeds.len(), 10_000);
    }

    #[test]
    fn stream_generators_decorrelated() {
        // Adjacent streams must not produce overlapping prefixes.
        let a: Vec<u64> = {
            let mut r = Xoshiro256pp::seed_for_stream(9, 0);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Xoshiro256pp::seed_for_stream(9, 1);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, b);
        assert!(a.iter().all(|x| !b.contains(x)));
    }

    #[test]
    fn jump_produces_disjoint_prefix() {
        let mut r = Xoshiro256pp::seed_from_u64(123);
        let child = r.split();
        let mut c = child;
        let a: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        let b: Vec<u64> = {
            let mut r = r;
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, b);
    }
}
