//! Zipf-distributed sampling over `{0, …, n−1}`.
//!
//! Used to give synthetic datasets the frequency skew the paper relies on:
//! word frequencies for the GloVe analog, node visit counts for the
//! metapath2vec analog, and merchant/category size imbalance for §5.3
//! (restaurants ≫ ambulance services).

use super::Rng;

/// Zipf(s) sampler over ranks `0..n` via inverse-CDF on a precomputed
/// cumulative table (O(n) setup, O(log n) sample). Rank 0 is the most
/// frequent element.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// `n` support size, `s` exponent (s=1.0 ≈ classic Zipf).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf support must be non-empty");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    /// Probability mass of rank `k`.
    pub fn pmf(&self, k: usize) -> f64 {
        let lo = if k == 0 { 0.0 } else { self.cdf[k - 1] };
        self.cdf[k] - lo
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw a rank in `[0, n)`.
    pub fn sample<R: Rng>(&self, rng: &mut R) -> usize {
        let u = rng.f64();
        // partition_point: first index with cdf[i] >= u.
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    /// Expected counts for `total` draws (used by generators that want the
    /// skew without sampling noise).
    pub fn expected_counts(&self, total: usize) -> Vec<usize> {
        (0..self.len())
            .map(|k| ((self.pmf(k) * total as f64).round() as usize).max(1))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn cdf_monotone_and_normalized() {
        let z = Zipf::new(100, 1.0);
        for w in z.cdf.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!((z.cdf.last().unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rank_zero_most_frequent() {
        let z = Zipf::new(50, 1.1);
        let mut r = Xoshiro256pp::seed_from_u64(1);
        let mut counts = vec![0usize; 50];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[1] > counts[30]);
        // Empirical head mass close to pmf.
        let p0 = counts[0] as f64 / 20_000.0;
        assert!((p0 - z.pmf(0)).abs() < 0.02, "p0={p0} pmf={}", z.pmf(0));
    }

    #[test]
    fn samples_in_range() {
        let z = Zipf::new(7, 0.8);
        let mut r = Xoshiro256pp::seed_from_u64(2);
        for _ in 0..1000 {
            assert!(z.sample(&mut r) < 7);
        }
    }

    #[test]
    fn expected_counts_sum_close_to_total() {
        let z = Zipf::new(20, 1.0);
        let c = z.expected_counts(10_000);
        let sum: usize = c.iter().sum();
        assert!((sum as i64 - 10_000).abs() < 100, "sum={sum}");
        assert!(c.iter().all(|&x| x >= 1));
    }
}
