//! Typed experiment configuration.
//!
//! Mirrors the paper's hyper-parameters: the coding scheme `(c, m)`
//! (Section 3.1), the decoder `(l, d_c, d_m, d_e)` and light/full variant
//! (Section 3.2), and per-task training settings (Appendix B.2 / C.1 /
//! Section 5.3.2). All configs round-trip through [`crate::ser::Json`] so
//! experiments are fully reproducible from a single file.

use crate::ser::Json;
use crate::{Error, Result};

/// Compositional-code format: cardinality `c` (power of two) and length `m`.
/// A code costs `m·log2(c)` bits per node (Section 3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CodingCfg {
    pub c: usize,
    pub m: usize,
}

impl CodingCfg {
    pub fn new(c: usize, m: usize) -> Result<Self> {
        let cfg = Self { c, m };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.c < 2 || !self.c.is_power_of_two() {
            return Err(Error::Config(format!("c must be a power of two ≥ 2, got {}", self.c)));
        }
        if self.m == 0 {
            return Err(Error::Config("m must be positive".into()));
        }
        Ok(())
    }

    /// Bits per element of the integer code (`log2 c`).
    pub fn bits_per_element(&self) -> usize {
        self.c.trailing_zeros() as usize
    }

    /// Total bits per node: `m·log2(c)`.
    pub fn n_bits(&self) -> usize {
        self.m * self.bits_per_element()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![("c", Json::num(self.c as f64)), ("m", Json::num(self.m as f64))])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Self::new(v.get("c")?.as_usize()?, v.get("m")?.as_usize()?)
    }
}

/// Parallel encode-engine settings (§Perf): how Algorithm 1 is *executed*,
/// deliberately separate from [`CodingCfg`], which defines *what* is
/// computed — by construction these knobs never change encode output
/// (bit-identical for every `threads`/`block_bits` choice, see
/// [`crate::lsh::encode_with`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EncodeCfg {
    /// Worker threads; `0` = use all available parallelism.
    pub threads: usize,
    /// Projections carried per pass over the auxiliary matrix;
    /// `0` = auto (one 64-bit word per pass).
    pub block_bits: usize,
}

impl Default for EncodeCfg {
    fn default() -> Self {
        Self { threads: 0, block_bits: 0 }
    }
}

impl EncodeCfg {
    pub fn new(threads: usize, block_bits: usize) -> Self {
        Self { threads, block_bits }
    }

    /// Reference single-thread execution (still blocked, still word-packed).
    pub fn single_thread() -> Self {
        Self { threads: 1, block_bits: 0 }
    }

    /// Resolve `threads = 0` against the machine.
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        }
    }

    /// Resolve `block_bits = 0` (default: one packed word per pass) and
    /// clamp to the code width.
    pub fn resolved_block_bits(&self, n_bits: usize) -> usize {
        let raw = if self.block_bits > 0 { self.block_bits } else { 64 };
        raw.clamp(1, n_bits.max(1))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("threads", Json::num(self.threads as f64)),
            ("block_bits", Json::num(self.block_bits as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            threads: v.get("threads")?.as_usize()?,
            block_bits: v.get("block_bits")?.as_usize()?,
        })
    }
}

/// Which execution backend runs the train/pred executables
/// ([`crate::runtime`]). `Auto` prefers AOT HLO artifacts when the `xla`
/// feature is compiled in and the files exist, and otherwise falls back to
/// the pure-Rust native backend so the full pipeline runs offline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// HLO artifacts when available (with the `xla` feature), else native.
    #[default]
    Auto,
    /// Pure-Rust forward/backward/AdamW engine ([`crate::runtime::native`]).
    Native,
    /// AOT-compiled HLO via PJRT only (errors when artifacts are missing
    /// or the build uses the offline xla stub).
    Xla,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "native" | "rust" => Ok(BackendKind::Native),
            "xla" | "hlo" | "pjrt" => Ok(BackendKind::Xla),
            other => Err(Error::Config(format!(
                "unknown backend '{other}' (expected auto | native | xla)"
            ))),
        }
    }
}

/// Decoder variant (Section 3.2 / Figure 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecoderVariant {
    /// Frozen random codebooks + trainable rescale vector `W0`.
    Light,
    /// Trainable codebooks (no `W0`).
    Full,
}

impl DecoderVariant {
    pub fn as_str(&self) -> &'static str {
        match self {
            DecoderVariant::Light => "light",
            DecoderVariant::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "light" => Ok(DecoderVariant::Light),
            "full" => Ok(DecoderVariant::Full),
            other => Err(Error::Config(format!("unknown decoder variant '{other}'"))),
        }
    }
}

/// Decoder model: `m` codebooks of shape `(c, d_c)`, then an `l`-layer MLP
/// `d_c → d_m → … → d_e` with ReLU between linear layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecoderCfg {
    pub coding: CodingCfg,
    /// Codebook vector dimension.
    pub d_c: usize,
    /// MLP hidden width.
    pub d_m: usize,
    /// Output embedding dimension.
    pub d_e: usize,
    /// Number of MLP linear layers (`l ≥ 2` per the paper's accounting).
    pub l: usize,
    pub variant: DecoderVariant,
}

impl DecoderCfg {
    /// Paper defaults for the OGB experiments (Appendix C.1):
    /// `l=3, d_c=d_m=512, d_e=64`.
    pub fn paper_ogb(coding: CodingCfg, variant: DecoderVariant) -> Self {
        Self { coding, d_c: 512, d_m: 512, d_e: 64, l: 3, variant }
    }

    pub fn validate(&self) -> Result<()> {
        self.coding.validate()?;
        if self.l < 2 {
            return Err(Error::Config(format!("decoder requires l ≥ 2, got {}", self.l)));
        }
        for (name, v) in [("d_c", self.d_c), ("d_m", self.d_m), ("d_e", self.d_e)] {
            if v == 0 {
                return Err(Error::Config(format!("{name} must be positive")));
            }
        }
        Ok(())
    }

    /// Codebook parameter count `m·c·d_c` (trainable for Full, frozen for
    /// Light — Section 3.2).
    pub fn codebook_params(&self) -> usize {
        self.coding.m * self.coding.c * self.d_c
    }

    /// MLP parameter count `d_c·d_m + (l−2)·d_m² + d_m·d_e` (weights only,
    /// matching the paper's formula; biases tracked separately).
    pub fn mlp_weight_params(&self) -> usize {
        self.d_c * self.d_m + (self.l - 2) * self.d_m * self.d_m + self.d_m * self.d_e
    }

    /// Bias parameter count for the MLP (`(l−1)·d_m + d_e`).
    pub fn mlp_bias_params(&self) -> usize {
        (self.l - 1) * self.d_m + self.d_e
    }

    /// Trainable parameters exactly as accounted in Section 3.2
    /// (weights-only formula, as the paper writes it).
    pub fn trainable_params_paper(&self) -> usize {
        match self.variant {
            DecoderVariant::Light => self.d_c + self.mlp_weight_params(),
            DecoderVariant::Full => self.codebook_params() + self.mlp_weight_params(),
        }
    }

    /// Non-trainable parameters (Light keeps `m·c·d_c` frozen codebooks,
    /// storable off-GPU).
    pub fn frozen_params(&self) -> usize {
        match self.variant {
            DecoderVariant::Light => self.codebook_params(),
            DecoderVariant::Full => 0,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("coding", self.coding.to_json()),
            ("d_c", Json::num(self.d_c as f64)),
            ("d_m", Json::num(self.d_m as f64)),
            ("d_e", Json::num(self.d_e as f64)),
            ("l", Json::num(self.l as f64)),
            ("variant", Json::str(self.variant.as_str())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let cfg = Self {
            coding: CodingCfg::from_json(v.get("coding")?)?,
            d_c: v.get("d_c")?.as_usize()?,
            d_m: v.get("d_m")?.as_usize()?,
            d_e: v.get("d_e")?.as_usize()?,
            l: v.get("l")?.as_usize()?,
            variant: DecoderVariant::parse(v.get("variant")?.as_str()?)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Which coding scheme produces the compositional codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Coder {
    /// ALONE baseline: codes drawn uniformly at random.
    Random,
    /// The paper's contribution: random-projection LSH with median
    /// threshold (Algorithm 1).
    Hash,
    /// Autoencoder baseline (Shu & Nakayama 2018) — needs pre-trained
    /// embeddings, only valid for reconstruction experiments.
    Learned,
}

impl Coder {
    pub fn as_str(&self) -> &'static str {
        match self {
            Coder::Random => "random",
            Coder::Hash => "hash",
            Coder::Learned => "learned",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "random" | "rand" | "alone" => Ok(Coder::Random),
            "hash" | "hashing" | "lsh" => Ok(Coder::Hash),
            "learned" | "learn" | "ae" => Ok(Coder::Learned),
            other => Err(Error::Config(format!("unknown coder '{other}'"))),
        }
    }
}

/// GNN architecture selector (Section 5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GnnKind {
    Sage,
    Gcn,
    Sgc,
    Gin,
}

impl GnnKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            GnnKind::Sage => "sage",
            GnnKind::Gcn => "gcn",
            GnnKind::Sgc => "sgc",
            GnnKind::Gin => "gin",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sage" | "graphsage" => Ok(GnnKind::Sage),
            "gcn" => Ok(GnnKind::Gcn),
            "sgc" => Ok(GnnKind::Sgc),
            "gin" => Ok(GnnKind::Gin),
            other => Err(Error::Config(format!("unknown gnn '{other}'"))),
        }
    }

    pub fn all() -> [GnnKind; 4] {
        [GnnKind::Sage, GnnKind::Gcn, GnnKind::Sgc, GnnKind::Gin]
    }
}

/// Optimizer settings (AdamW; paper uses PyTorch defaults or lr=0.01).
#[derive(Clone, Copy, Debug)]
pub struct OptimCfg {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl OptimCfg {
    /// PyTorch AdamW defaults (Appendix B.2).
    pub fn adamw_default() -> Self {
        Self { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.01 }
    }

    /// GNN training settings (Appendix C.1 / §5.3.2): lr=0.01, wd=0.
    pub fn adamw_gnn() -> Self {
        Self { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lr", Json::num(self.lr as f64)),
            ("beta1", Json::num(self.beta1 as f64)),
            ("beta2", Json::num(self.beta2 as f64)),
            ("eps", Json::num(self.eps as f64)),
            ("weight_decay", Json::num(self.weight_decay as f64)),
        ])
    }
}

/// Training-loop settings.
#[derive(Clone, Copy, Debug)]
pub struct TrainCfg {
    pub epochs: usize,
    pub batch_size: usize,
    pub seed: u64,
    pub optim: OptimCfg,
    /// Log every `log_every` steps (0 = silent).
    pub log_every: usize,
}

impl TrainCfg {
    pub fn new(epochs: usize, batch_size: usize, seed: u64, optim: OptimCfg) -> Self {
        Self { epochs, batch_size, seed, optim, log_every: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coding_bit_math_matches_paper_examples() {
        // Paper §1: c=4, m=6 → 12 bits; c=64, m=8 → 48 bits.
        assert_eq!(CodingCfg::new(4, 6).unwrap().n_bits(), 12);
        assert_eq!(CodingCfg::new(64, 8).unwrap().n_bits(), 48);
        // Appendix B.2: c=2, m=128 → 128 bits; c=256, m=16 → 128 bits.
        assert_eq!(CodingCfg::new(2, 128).unwrap().n_bits(), 128);
        assert_eq!(CodingCfg::new(256, 16).unwrap().n_bits(), 128);
    }

    #[test]
    fn coding_rejects_non_power_of_two() {
        assert!(CodingCfg::new(3, 8).is_err());
        assert!(CodingCfg::new(0, 8).is_err());
        assert!(CodingCfg::new(1, 8).is_err());
        assert!(CodingCfg::new(2, 0).is_err());
    }

    #[test]
    fn encode_cfg_resolution_and_roundtrip() {
        let auto = EncodeCfg::default();
        assert!(auto.resolved_threads() >= 1);
        assert_eq!(auto.resolved_block_bits(128), 64);
        assert_eq!(auto.resolved_block_bits(12), 12);
        let one = EncodeCfg::single_thread();
        assert_eq!(one.resolved_threads(), 1);
        let fixed = EncodeCfg::new(4, 96);
        assert_eq!(fixed.resolved_threads(), 4);
        assert_eq!(fixed.resolved_block_bits(128), 96);
        assert_eq!(fixed.resolved_block_bits(32), 32);
        let back = EncodeCfg::from_json(&fixed.to_json()).unwrap();
        assert_eq!(fixed, back);
    }

    #[test]
    fn decoder_param_formulas() {
        // §5.3.2 settings: l=3, d_c=d_m=512, d_e=64, c=256, m=16.
        let cfg = DecoderCfg {
            coding: CodingCfg::new(256, 16).unwrap(),
            d_c: 512,
            d_m: 512,
            d_e: 64,
            l: 3,
            variant: DecoderVariant::Full,
        };
        assert_eq!(cfg.codebook_params(), 16 * 256 * 512);
        assert_eq!(cfg.mlp_weight_params(), 512 * 512 + 512 * 512 + 512 * 64);
        assert_eq!(
            cfg.trainable_params_paper(),
            16 * 256 * 512 + 512 * 512 + 512 * 512 + 512 * 64
        );
        assert_eq!(cfg.frozen_params(), 0);

        let light = DecoderCfg { variant: DecoderVariant::Light, ..cfg };
        assert_eq!(light.trainable_params_paper(), 512 + light.mlp_weight_params());
        assert_eq!(light.frozen_params(), 16 * 256 * 512);
    }

    #[test]
    fn decoder_validation() {
        let mut cfg = DecoderCfg::paper_ogb(CodingCfg::new(16, 32).unwrap(), DecoderVariant::Full);
        assert!(cfg.validate().is_ok());
        cfg.l = 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn decoder_json_roundtrip() {
        let cfg = DecoderCfg::paper_ogb(CodingCfg::new(16, 32).unwrap(), DecoderVariant::Light);
        let j = cfg.to_json();
        let back = DecoderCfg::from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn parse_enums() {
        assert_eq!(Coder::parse("alone").unwrap(), Coder::Random);
        assert_eq!(Coder::parse("lsh").unwrap(), Coder::Hash);
        assert_eq!(GnnKind::parse("graphsage").unwrap(), GnnKind::Sage);
        assert!(GnnKind::parse("gat").is_err());
    }

    #[test]
    fn parse_backend_kind() {
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("rust").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("cuda").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Auto);
        assert_eq!(BackendKind::Native.as_str(), "native");
    }
}
