//! §5.3 application example: merchant-category identification on a
//! consumer–merchant transaction graph (Figure 5's "GNN model" component).
//!
//! The pipeline mirrors the paper's production story: the graph is far too
//! large for an explicit embedding table, so nodes are compressed to
//! 128-bit codes (Algorithm 1 over adjacency), and minibatch GraphSAGE +
//! decoder trains end to end. Reports acc / hit@k on held-out merchants.
//!
//! Run: `cargo run --release --example merchant_pipeline -- [epochs]`

use hashgnn::cfg::Coder;
use hashgnn::runtime::Engine;
use hashgnn::tasks::{memory, merchant};

fn main() -> hashgnn::Result<()> {
    let epochs: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let seed = 11u64;
    let engine = Engine::cpu("artifacts")?;
    let model = engine.load("merchant")?;

    eprintln!("== merchant-category identification (§5.3 analog) ==");
    let t0 = std::time::Instant::now();
    let bip = merchant::build_graph(&model, seed)?;
    let n_tx = bip.graph.undirected_edges().len();
    eprintln!(
        "[{:5.1}s] transaction graph: {} consumers, {} merchants, {} categories, {} edges",
        t0.elapsed().as_secs_f64(),
        bip.n_consumers,
        bip.n_merchants,
        bip.n_categories,
        n_tx
    );

    // Memory story (the reason the NC baseline is absent, §5.3.2): what an
    // explicit table would cost at paper scale vs what the codes cost here.
    let coding = hashgnn::cfg::CodingCfg::new(256, 16)?;
    println!(
        "embedding memory at paper scale (17.9M nodes): raw {} MiB vs codes {} MiB",
        (memory::raw_bytes(17_943_972, 64) as f64 / memory::MIB).round(),
        (memory::code_bytes(17_943_972, coding) as f64 / memory::MIB).round(),
    );

    let hash = merchant::run(&engine, &bip, Coder::Hash, epochs, seed)?;
    eprintln!("[{:5.1}s] hash arm done", t0.elapsed().as_secs_f64());
    println!(
        "hash: acc {:.4} | hit@5 {:.4} | hit@10 {:.4} | hit@20 {:.4}",
        hash.metrics.accuracy, hash.metrics.hit5, hash.metrics.hit10, hash.metrics.hit20
    );
    println!("(run `cargo bench --bench table3_merchant` for the full Rand-vs-Hash table)");
    println!("total wall time {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
