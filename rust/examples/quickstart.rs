//! Quickstart: the paper's two-stage method on a tiny graph, end to end.
//!
//! 1. Build a graph (no node features — the setting the paper targets).
//! 2. **Encode** (Algorithm 1): every node gets an `m·log2(c)`-bit
//!    compositional code from random-projection LSH over its adjacency
//!    row, binarized at the median.
//! 3. **Decode**: the AOT-compiled decoder (codebooks + MLP) turns codes
//!    into dense embeddings via the PJRT runtime.
//!
//! Run: `cargo run --release --example quickstart`

use hashgnn::cfg::CodingCfg;
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::lsh::{encode, Threshold};
use hashgnn::params::ParamStore;
use hashgnn::runtime::{Engine, Tensor};
use hashgnn::train;

fn main() -> hashgnn::Result<()> {
    // --- 1. a featureless graph -----------------------------------------
    let graph = sbm(SbmCfg::new(2000, 4, 12.0, 2.0), 42)?;
    println!(
        "graph: {} nodes, {} undirected edges, {} communities",
        graph.n_nodes(),
        graph.undirected_edges().len(),
        graph.n_classes()
    );

    // --- 2. encoding stage (Algorithm 1) --------------------------------
    let coding = CodingCfg::new(16, 32)?; // 128-bit codes
    let table = encode(graph.adj(), coding, Threshold::Median, 7)?;
    println!(
        "codes: {} bits/node, {} KiB total, {} collisions",
        coding.n_bits(),
        table.bits.storage_bytes() / 1024,
        table.bits.n_collisions()
    );
    println!("node 0 integer code: {:?}", &table.int_code(0)[..8.min(coding.m)]);

    // --- 3. decoding stage (AOT decoder through PJRT) -------------------
    let engine = Engine::cpu("artifacts")?;
    let model = engine.load("recon_c16_m32")?;
    let store = ParamStore::init(&model.manifest, 1);
    let b = model.manifest.hyper_usize("batch")?;
    let ids: Vec<u32> = (0..b as u32).map(|i| i % graph.n_nodes() as u32).collect();
    let mut code_buf = Vec::new();
    table.gather_int_codes(&ids, &mut code_buf);
    let emb = train::predict(
        &model,
        &store,
        &[Tensor::i32(vec![b, coding.m], code_buf)?],
    )?;
    let d_e = model.manifest.hyper_usize("d_e")?;
    println!(
        "decoded {} embeddings of dim {d_e}; node 0 -> [{:.3}, {:.3}, {:.3}, ...]",
        b,
        emb.as_f32()?[0],
        emb.as_f32()?[1],
        emb.as_f32()?[2]
    );
    println!("\nquickstart OK — see examples/train_nodeclf.rs for full training");
    Ok(())
}
