//! End-to-end validation driver (DESIGN.md §7): trains minibatch
//! GraphSAGE + the compression decoder for several hundred steps on a
//! 10k-node synthetic community graph, logging the loss curve and final
//! accuracy. All three layers compose here: L3 sampling/batching (rust) →
//! L2 GNN+decoder step (JAX, AOT) → L1 Pallas kernels inside it.
//!
//! Run: `cargo run --release --example train_nodeclf -- [epochs] [coder]`
//! (defaults: 5 epochs, hash coding). Results are recorded in
//! EXPERIMENTS.md.

use std::sync::Arc;

use hashgnn::cfg::{Coder, CodingCfg};
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::graph::split_nodes;
use hashgnn::runtime::Engine;
use hashgnn::tasks::coding::{make_codes, Aux};
use hashgnn::tasks::sage::{self, Features, SageTask};

fn main() -> hashgnn::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let epochs: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(5);
    let coder = Coder::parse(args.get(1).map(|s| s.as_str()).unwrap_or("hash"))
        .unwrap_or(Coder::Hash);
    let seed = 42u64;

    let engine = Engine::cpu("artifacts")?;
    let model = engine.load("sage_mb_coded")?;
    let n = model.manifest.hyper_usize("n")?;
    let k = model.manifest.hyper_usize("n_classes")?;
    let coding = CodingCfg::new(
        model.manifest.hyper_usize("c")?,
        model.manifest.hyper_usize("m")?,
    )?;

    eprintln!("== e2e: minibatch GraphSAGE + {} coding on SBM n={n} ==", coder.as_str());
    let t0 = std::time::Instant::now();
    let graph = Arc::new(sbm(SbmCfg::new(n, k, 12.0, 2.0), seed)?);
    eprintln!("[{:6.1}s] graph built: {} edges", t0.elapsed().as_secs_f64(),
        graph.undirected_edges().len());

    let codes = make_codes(&Aux::Graph(&graph), coder, coding, seed)?;
    eprintln!(
        "[{:6.1}s] encoded: {} bits/node, {} collisions",
        t0.elapsed().as_secs_f64(),
        coding.n_bits(),
        codes.bits.n_collisions()
    );

    let labels = Arc::new(graph.labels().expect("labels").to_vec());
    let split = split_nodes(n, 0.7, 0.1, seed ^ 0xA5)?;
    let task = SageTask {
        graph: graph.clone(),
        labels: labels.clone(),
        features: Features::Codes(Arc::new(codes.clone())),
        train_nodes: Arc::new(split.train.clone()),
    };

    let run = sage::train_sage(&model, task, epochs, &split.val, seed, 5)?;
    eprintln!("[{:6.1}s] training done ({} steps)", t0.elapsed().as_secs_f64(), run.losses.len());

    // Loss curve (the §7 deliverable): print a compact summary.
    let chunk = (run.losses.len() / 10).max(1);
    println!("\nloss curve (mean per {chunk}-step window):");
    for (i, w) in run.losses.chunks(chunk).enumerate() {
        let mean: f32 = w.iter().sum::<f32>() / w.len() as f32;
        println!("  steps {:>4}-{:<4}  loss {mean:.4}", i * chunk, i * chunk + w.len() - 1);
    }

    let batcher = sage::SageBatcher::new(
        SageTask {
            graph,
            labels,
            features: Features::Codes(Arc::new(codes)),
            train_nodes: Arc::new(split.train),
        },
        &model,
        seed,
    )?;
    let test = sage::evaluate(&model, &run.store, &batcher, &split.test, seed ^ 0x99)?;
    println!(
        "\nbest-val accuracy {:.4} | test accuracy {:.4} ({} classes, chance {:.4})",
        run.best_val.accuracy,
        test.accuracy,
        k,
        1.0 / k as f64
    );
    println!("total wall time {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
