//! §5.1 workflow example: compress a set of pre-trained embeddings and
//! measure reconstruction quality (the Figure-1 proxy task).
//!
//! Uses the metapath2vec analog (Gaussian-mixture node embeddings with
//! cluster labels): encode → train decoder with MSE → reconstruct →
//! k-means + NMI against the ground-truth clusters, for both the random
//! (ALONE) and hashing coders.
//!
//! Run: `cargo run --release --example compress_embeddings -- [n_entities]`

use hashgnn::cfg::{Coder, CodingCfg};
use hashgnn::embed::gaussian_mixture;
use hashgnn::runtime::Engine;
use hashgnn::tasks::coding::{make_codes, Aux};
use hashgnn::tasks::recon;

fn main() -> hashgnn::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5000);
    let seed = 3u64;
    let epochs = 8;
    let eval_k = 2000.min(n);

    let engine = Engine::cpu("artifacts")?;
    let model = engine.load("recon_c16_m32")?;
    let coding = CodingCfg::new(16, 32)?;

    eprintln!("== embedding compression on metapath2vec-analog ({n} entities) ==");
    let set = gaussian_mixture(n, 128, 8, 0.25, seed);
    let labels = set.labels.clone().expect("mixture labels");

    // Upper bound: clustering quality of the *raw* embeddings.
    let raw_nmi = recon::clustering_nmi(&set.data[..eval_k * set.d], eval_k, set.d, &labels, 8, 1);
    println!("raw (no compression) NMI: {raw_nmi:.4}");

    for coder in [Coder::Random, Coder::Hash] {
        let t0 = std::time::Instant::now();
        let codes = make_codes(
            &Aux::Dense { data: &set.data, n: set.n, d: set.d },
            coder,
            coding,
            seed,
        )?;
        let (store, log) = recon::train_decoder(&model, &codes, &set, epochs, seed)?;
        let emb = recon::reconstruct(&model, &store, &codes, eval_k)?;
        let nmi = recon::clustering_nmi(&emb, eval_k, set.d, &labels, 8, 1);
        println!(
            "{:>6}: NMI {:.4} (final mse {:.4}, {} steps, {:.1}s)",
            coder.as_str(),
            nmi,
            log.tail_mean(5),
            log.losses.len(),
            t0.elapsed().as_secs_f64()
        );
    }
    println!("(expected shape: hash ≥ random, both ≤ raw — Figure 1's middle panel)");
    Ok(())
}
