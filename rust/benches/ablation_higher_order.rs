//! Ablation (paper §6.1, future work): higher-order adjacency as the
//! auxiliary information for Algorithm 1.
//!
//! The paper suggests replacing `A` with `A²`-style higher-order
//! connectivity, hypothesizing that broader-scope auxiliary information
//! yields better codes. We test exactly that: encode with `A` vs `A + A²`
//! and compare (a) code-collision counts, (b) the intra/inter-class code
//! similarity gap, and (c) downstream full-batch GCN accuracy.

mod bench_util;

use hashgnn::cfg::{Coder, CodingCfg, GnnKind};
use hashgnn::codes::CodeTable;
use hashgnn::graph::Graph;
use hashgnn::lsh::{self, Threshold};
use hashgnn::report::Table;
use hashgnn::runtime::{Engine, Tensor};
use hashgnn::tasks::nodeclf::{self, Frontend, RunOpts};
use hashgnn::tasks::T1Dataset;

/// Encode with `A + A²` (second-order connectivity) as auxiliary info.
fn encode_second_order(graph: &Graph, coding: CodingCfg, seed: u64) -> hashgnn::Result<CodeTable> {
    let a2 = graph.adj().square()?;
    // A + A²: keep first-order structure, add two-hop counts.
    let n = graph.n_nodes();
    let mut triplets: Vec<(u32, u32, f32)> = Vec::new();
    for r in 0..n {
        for (k, &c) in graph.adj().row_indices(r).iter().enumerate() {
            triplets.push((r as u32, c, graph.adj().row_values(r)[k]));
        }
        for (k, &c) in a2.row_indices(r).iter().enumerate() {
            triplets.push((r as u32, c, 0.5 * a2.row_values(r)[k]));
        }
    }
    let combined = hashgnn::sparse::Csr::from_triplets(n, n, &triplets)?;
    Ok(lsh::encode(&combined, coding, Threshold::Median, seed)?)
}

fn main() -> hashgnn::Result<()> {
    bench_util::banner("ablation_higher_order", "§6.1 extension: A vs A+A² auxiliary info");
    let engine = Engine::cpu("artifacts")?;
    let coding = CodingCfg::new(16, 32)?;
    let seed = 7u64;
    let epochs = bench_util::pick(80, 8);

    let mut t = Table::new(
        "higher-order auxiliary information ablation (GCN node classification)",
        &["dataset", "aux", "collisions", "intra-inter gap", "test acc"],
    );
    for ds in T1Dataset::nodeclf_all() {
        let graph = ds.generate(11)?;
        for (label, codes) in [
            ("A", lsh::encode(graph.adj(), coding, Threshold::Median, seed)?),
            ("A+A^2", encode_second_order(&graph, coding, seed)?),
        ] {
            // Code quality.
            let gap = code_gap(&graph, &codes);
            // Downstream accuracy: inject the codes directly.
            let acc = run_gcn_with_codes(&engine, &graph, &codes, epochs)?;
            t.row(vec![
                ds.name().into(),
                label.into(),
                codes.bits.n_collisions().to_string(),
                format!("{gap:.4}"),
                format!("{acc:.4}"),
            ]);
        }
    }
    println!("{}", t.render());
    println!("expected shape (paper §6.1 hypothesis): A+A² ≥ A on gap and accuracy");
    Ok(())
}

/// Intra- vs inter-class code similarity gap (labels from the SBM).
fn code_gap(graph: &Graph, codes: &CodeTable) -> f64 {
    use hashgnn::rng::{Rng, Xoshiro256pp};
    let labels = graph.labels().expect("labeled graph");
    let n = graph.n_nodes();
    let bits = codes.coding.n_bits();
    let mut rng = Xoshiro256pp::seed_from_u64(99);
    let (mut intra, mut inter, mut ni, mut no) = (0.0f64, 0.0f64, 0usize, 0usize);
    for _ in 0..6000 {
        let a = rng.index(n);
        let b = rng.index(n);
        if a == b {
            continue;
        }
        let same =
            (0..bits).filter(|&k| codes.bits.get(a, k) == codes.bits.get(b, k)).count() as f64
                / bits as f64;
        if labels[a] == labels[b] {
            intra += same;
            ni += 1;
        } else {
            inter += same;
            no += 1;
        }
    }
    intra / ni.max(1) as f64 - inter / no.max(1) as f64
}

/// Full-batch GCN with externally supplied codes (bypasses the coder
/// dispatch so both arms share everything but the auxiliary matrix).
fn run_gcn_with_codes(
    engine: &Engine,
    graph: &Graph,
    codes: &CodeTable,
    epochs: usize,
) -> hashgnn::Result<f64> {
    use hashgnn::graph::split_nodes;
    use hashgnn::params::ParamStore;
    use hashgnn::train;

    let model = engine.load("node_fb_gcn_coded")?;
    let n = graph.n_nodes();
    let k = model.manifest.hyper_usize("n_classes")?;
    let labels = graph.labels().expect("labels");
    let native = model.backend_name() == "native";
    let adj = nodeclf::adj_input(graph, model.manifest.hyper_str("adj")?, native)?;
    let ids: Vec<u32> = (0..n as u32).collect();
    let mut buf = Vec::new();
    codes.gather_int_codes(&ids, &mut buf);
    let codes_t = Tensor::i32(vec![n, codes.coding.m], buf)?;

    let opts = RunOpts { epochs, eval_every: 10, seed: 7 };
    let split = split_nodes(n, 0.7, 0.1, opts.seed ^ 0xA5A5)?;
    let mut mask = vec![0.0f32; n];
    for &i in &split.train {
        mask[i as usize] = 1.0;
    }
    let mut batch = vec![codes_t.clone()];
    match &adj {
        nodeclf::AdjInput::Csr(a) => model.bind_adjacency(a.clone())?,
        nodeclf::AdjInput::Dense(t) => batch.push(t.clone()),
    }
    let pred_batch = batch.clone();
    batch.push(Tensor::i32(vec![n], labels.iter().map(|&l| l as i32).collect())?);
    batch.push(Tensor::f32(vec![n], mask)?);
    let mut store = ParamStore::init(&model.manifest, opts.seed);
    let mut best = (f64::MIN, 0.0f64);
    for epoch in 0..opts.epochs {
        train::run_step(&model, &mut store, &batch)?;
        if (epoch + 1) % opts.eval_every == 0 || epoch + 1 == opts.epochs {
            let logits = train::predict(&model, &store, &pred_batch)?;
            let (val, test) = nodeclf::split_accuracy(logits.as_f32()?, n, k, labels, &split);
            if val > best.0 {
                best = (val, test);
            }
        }
    }
    Ok(best.1)
}
