//! Tables 4 & 6 — compression ratios vs number of compressed entities and
//! vs the (c, m) setting, at the paper's own dimensions. Analytic; the
//! unit tests in tasks::memory pin these to the paper's printed values.

mod bench_util;

use hashgnn::cfg::CodingCfg;
use hashgnn::report::Table;
use hashgnn::tasks::memory::compression_ratio;

fn main() -> hashgnn::Result<()> {
    bench_util::banner("table4_6_ratios", "Tables 4 and 6 (compression ratios)");
    let counts = [5000usize, 10000, 25000, 50000, 100000, 200000];

    // Table 4: (c=2, m=128), d_c=d_m=512.
    let mut t4 = Table::new(
        "Table 4 — compression ratio vs #entities (c=2, m=128)",
        &["embedding", "5000", "10000", "25000", "50000", "100000", "200000"],
    );
    for (name, d_raw, d_e) in [("GloVe", 300usize, 300usize), ("metapath2vec", 128, 128)] {
        let mut row = vec![name.to_string()];
        for &n in &counts {
            row.push(format!(
                "{:.2}",
                compression_ratio(n, d_raw, CodingCfg::new(2, 128)?, 512, 512, d_e)
            ));
        }
        t4.row(row);
    }
    println!("{}", t4.render());

    // Table 6: the (c, m) grid at four entity counts.
    let grid = [(2usize, 128usize), (4, 64), (16, 32), (256, 16)];
    let sub = [5000usize, 10000, 50000, 200000];
    let mut t6 = Table::new(
        "Table 6 — compression ratio vs (c, m)",
        &["embedding", "c", "m", "5000", "10000", "50000", "200000"],
    );
    for (name, d_raw, d_e) in [("GloVe", 300usize, 300usize), ("metapath2vec", 128, 128)] {
        for (c, m) in grid {
            let mut row = vec![name.to_string(), c.to_string(), m.to_string()];
            for &n in &sub {
                row.push(format!(
                    "{:.2}",
                    compression_ratio(n, d_raw, CodingCfg::new(c, m)?, 512, 512, d_e)
                ));
            }
            t6.row(row);
        }
    }
    println!("{}", t6.render());
    println!(
        "note: reproduces the paper's printed numbers exactly (see tasks::memory tests);\n\
         the paper's own §3.2 formula differs by the (l-2)·d_m² term — see DESIGN.md."
    );
    Ok(())
}
