//! Table 5 — reconstruction quality under different (c, m) settings,
//! random vs hashing coding, varying the number of compressed entities.
//!
//! Expected shape: hashing ≥ random in almost every cell, with the gap
//! widening as entity count grows; larger decoders (c=256, m=16) score
//! best overall.

mod bench_util;

use hashgnn::cfg::{Coder, CodingCfg};
use hashgnn::embed::gaussian_mixture;
use hashgnn::report::Table;
use hashgnn::runtime::Engine;
use hashgnn::tasks::coding::{make_codes, Aux};
use hashgnn::tasks::recon;

fn main() -> hashgnn::Result<()> {
    bench_util::banner("table5_cm_sweep", "Table 5 ((c,m) grid on reconstruction)");
    let engine = Engine::cpu("artifacts")?;
    let grid = [(2usize, 128usize), (4, 64), (16, 32), (256, 16)];
    let counts: Vec<usize> = bench_util::pick(vec![2000, 5000, 20000], vec![1500]);
    let epochs = bench_util::pick(8, 3);
    let eval_k = 1500;
    let seed = 5u64;

    let full = gaussian_mixture(*counts.last().unwrap(), 128, 8, 0.25, 9);
    let labels = full.labels.clone().expect("labels");
    let raw_nmi = recon::clustering_nmi(&full.data[..eval_k * 128], eval_k, 128, &labels, 8, 1);
    println!("raw upper bound NMI: {raw_nmi:.3}\n");

    let mut header = vec!["c".to_string(), "m".to_string(), "coder".to_string()];
    header.extend(counts.iter().map(|n| n.to_string()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 5 — metapath2vec* NMI across (c, m)", &header_refs);

    for (c, m) in grid {
        let coding = CodingCfg::new(c, m)?;
        let model = engine.load(&format!("recon_c{c}_m{m}"))?;
        for coder in [Coder::Random, Coder::Hash] {
            let mut row = vec![
                c.to_string(),
                m.to_string(),
                match coder {
                    Coder::Random => "random".to_string(),
                    _ => "hashing".to_string(),
                },
            ];
            for &n in &counts {
                let set = full.top(n);
                let aux = match coder {
                    Coder::Random => Aux::None { n },
                    _ => Aux::Dense { data: &set.data, n: set.n, d: set.d },
                };
                let codes = make_codes(&aux, coder, coding, seed)?;
                let (store, _) = recon::train_decoder(&model, &codes, &set, epochs, seed)?;
                let emb = recon::reconstruct(&model, &store, &codes, eval_k.min(n))?;
                let nmi = recon::clustering_nmi(&emb, eval_k.min(n), 128, &labels, 8, 1);
                eprintln!("  (c={c}, m={m}) {} n={n}: NMI {nmi:.3}", row[2]);
                row.push(format!("{nmi:.3}"));
            }
            t.row(row);
        }
    }
    println!("{}", t.render());
    Ok(())
}
