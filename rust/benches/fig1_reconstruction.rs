//! Figure 1 — reconstruction quality vs number of compressed entities.
//!
//! Series per panel: random (ALONE), hashing/pre-trained, hashing/graph
//! (adjacency), learned (autoencoder), and the raw upper bound. Panels:
//! GloVe analog (analogy accuracy + similarity ρ) and two
//! metapath2vec-analog sets (k-means NMI).
//!
//! Expected shape (paper): all coders ≈ raw at small n; random degrades
//! sharply as n grows; hashing tracks learned closely without any extra
//! training stage.

mod bench_util;

use hashgnn::cfg::{Coder, CodingCfg};
use hashgnn::embed::{analogy_embeddings, gaussian_mixture};
use hashgnn::graph::generate::{sbm_with_labels, SbmCfg};
use hashgnn::report::Table;
use hashgnn::runtime::Engine;
use hashgnn::tasks::coding::{make_codes, Aux};
use hashgnn::tasks::recon;

fn main() -> hashgnn::Result<()> {
    bench_util::banner("fig1_reconstruction", "Figure 1 (all six panels' series)");
    let engine = Engine::cpu("artifacts")?;
    let model = engine.load("recon_c16_m32")?;
    let ae = engine.load("ae_c16_m32")?;
    let coding = CodingCfg::new(16, 32)?;
    let counts: Vec<usize> = bench_util::pick(vec![2000, 5000, 10000, 20000], vec![1000, 3000]);
    let epochs = bench_util::pick(8, 3);
    let ae_epochs = bench_util::pick(6, 2);
    let eval_k = 2000;
    let seed = 5u64;

    // ---------------- GloVe-analog panel --------------------------------
    let glove = analogy_embeddings(*counts.last().unwrap(), 128, 14, 20, 400, 0.05, seed);
    let mut t_glove = Table::new(
        "Fig 1 (a,b) — GloVe* analogy accuracy / similarity rho vs #entities",
        &["#entities", "coder", "analogy", "similarity"],
    );
    {
        let (racc, rrho) = recon::eval_word(&glove.set.data[..eval_k * 128], eval_k, &glove);
        t_glove.row(vec!["-".into(), "raw".into(), format!("{racc:.3}"), format!("{rrho:.3}")]);
    }
    for &n in &counts {
        let set = glove.set.top(n);
        for coder in [Coder::Random, Coder::Hash] {
            let codes = make_codes(
                &Aux::Dense { data: &set.data, n: set.n, d: set.d },
                coder,
                coding,
                seed,
            )?;
            let (store, _) = recon::train_decoder(&model, &codes, &set, epochs, seed)?;
            let emb = recon::reconstruct(&model, &store, &codes, eval_k.min(n))?;
            let (acc, rho) = recon::eval_word(&emb, eval_k.min(n), &glove);
            let label = match coder {
                Coder::Hash => "hash/pre-trained",
                _ => "random",
            };
            t_glove.row(vec![
                n.to_string(),
                label.into(),
                format!("{acc:.3}"),
                format!("{rho:.3}"),
            ]);
        }
    }
    println!("{}", t_glove.render());

    // ------------- metapath2vec-analog panels ---------------------------
    for (panel, mix_seed) in [("metapath2vec*", 9u64), ("metapath2vec++*", 10u64)] {
        let full = gaussian_mixture(*counts.last().unwrap(), 128, 8, 0.25, mix_seed);
        let labels = full.labels.clone().expect("labels");
        let mut t = Table::new(
            &format!("Fig 1 — {panel} clustering NMI vs #entities"),
            &["#entities", "coder", "NMI"],
        );
        let raw_nmi =
            recon::clustering_nmi(&full.data[..eval_k * 128], eval_k, 128, &labels, 8, 1);
        t.row(vec!["-".into(), "raw".into(), format!("{raw_nmi:.3}")]);
        for &n in &counts {
            let set = full.top(n);
            // Graph consistent with the clusters (for the hashing/graph
            // arm): in the paper the graph *generated* the embeddings, so
            // its communities must match the mixture's labels.
            let graph = sbm_with_labels(
                SbmCfg::new(n, 8, 10.0, 2.0),
                labels[..n].to_vec(),
                mix_seed ^ 0xF00,
            )?;
            let arms: Vec<(&str, Aux)> = vec![
                ("random", Aux::None { n }),
                ("hash/pre-trained", Aux::Dense { data: &set.data, n: set.n, d: set.d }),
                ("hash/graph", Aux::Graph(&graph)),
            ];
            for (label, aux) in arms {
                let coder = if label == "random" { Coder::Random } else { Coder::Hash };
                let codes = make_codes(&aux, coder, coding, seed)?;
                let (store, _) = recon::train_decoder(&model, &codes, &set, epochs, seed)?;
                let emb = recon::reconstruct(&model, &store, &codes, eval_k.min(n))?;
                let nmi = recon::clustering_nmi(&emb, eval_k.min(n), 128, &labels, 8, 1);
                t.row(vec![n.to_string(), label.into(), format!("{nmi:.3}")]);
            }
            // Learned arm (autoencoder) on the first panel only (cost).
            if panel == "metapath2vec*" {
                let codes = recon::learned_codes(&ae, &set, n, ae_epochs, seed)?;
                let (store, _) = recon::train_decoder(&model, &codes, &set, epochs, seed)?;
                let emb = recon::reconstruct(&model, &store, &codes, eval_k.min(n))?;
                let nmi = recon::clustering_nmi(&emb, eval_k.min(n), 128, &labels, 8, 1);
                t.row(vec![n.to_string(), "learn".into(), format!("{nmi:.3}")]);
            }
        }
        println!("{}", t.render());
    }
    Ok(())
}
