//! §Perf — native-backend train-step throughput.
//!
//! Sweeps batch size × thread count over a CPU-budget §4 minibatch-SAGE
//! build (hash codes, decoder, CE head), plus the full-batch sparse path
//! (GCN / GIN over CSR adjacency, node-count × thread sweep) so the SpMM
//! propagation's scaling is tracked, plus the serving path
//! (`ServeSession::embed_nodes` batch × thread × cache-hit-rate sweep,
//! `rows_infer`), plus three before/after comparisons for the training
//! pipeline: pooled vs sequential neighbor sampling (`rows_sampler`),
//! step-scratch reuse vs fresh allocation (`rows_scratch`), and a
//! pipeline-depth sweep (`rows_pipeline`). Also asserts the backend's
//! determinism contract (bit-identical loss and served bytes across
//! thread counts, pooled samples == sequential, scratch reuse == fresh
//! alloc, loss curves identical across pipeline depths) on every run,
//! and emits machine-readable `BENCH_train_step.json` at the repo root.

mod bench_util;

use std::sync::Arc;

use bench_util::Samples;
use hashgnn::cfg::{CodingCfg, GnnKind, OptimCfg};
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::lsh::{self, Threshold};
use hashgnn::params::ParamStore;
use hashgnn::report::Table;
use hashgnn::runtime::native::spec::{FullBatchBuild, SageMbBuild};
use hashgnn::runtime::{Model, Tensor};
use hashgnn::ser::{self, Json};
use hashgnn::serve::{ServeOpts, ServeSession, ServingBundle};
use hashgnn::graph::NeighborSampler;
use hashgnn::tasks::sage::{Features, SageBatcher, SageTask};
use hashgnn::train::{self, BatchSource, TrainOpts};

fn build_for(batch: usize, n: usize) -> SageMbBuild {
    SageMbBuild {
        name: format!("bench_b{batch}"),
        coded: true,
        link: false,
        n,
        n_classes: 8,
        d_e: 32,
        hidden: 64,
        batch,
        k1: 5,
        k2: 5,
        c: 16,
        m: 16,
        d_c: 64,
        d_m: 64,
        l: 3,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    }
}

fn main() -> hashgnn::Result<()> {
    bench_util::banner("train_step", "native-backend train-step throughput (§Perf)");
    let n = bench_util::pick(4000, 1000);
    let steps = bench_util::pick(12u64, 3);
    let reps = bench_util::pick(3, 1);

    let coding = CodingCfg::new(16, 16)?;
    let g = Arc::new(sbm(SbmCfg::new(n, 8, 12.0, 2.0), 3)?);
    let labels = Arc::new(g.labels().unwrap().to_vec());
    let codes = Arc::new(lsh::encode(g.adj(), coding, Threshold::Median, 7)?);

    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut thread_counts = vec![1usize];
    if avail >= 2 {
        thread_counts.push(2);
    }
    if avail > 2 {
        thread_counts.push(avail);
    }

    let mut t = Table::new(
        "native train step (steps/s; bit-identical across threads)",
        &["batch", "threads", "steps/s", "ns/step"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut determinism_ok = true;

    for batch in [64usize, 128, 256] {
        let build = build_for(batch, n);
        let manifest = build.manifest();
        let mut reference_losses: Option<Vec<u32>> = None;
        for &threads in &thread_counts {
            let model = Model::native(manifest.clone(), threads)?;
            let run_once = || -> hashgnn::Result<Vec<f32>> {
                let task = SageTask {
                    graph: g.clone(),
                    labels: labels.clone(),
                    features: Features::Codes(codes.clone()),
                    train_nodes: Arc::new((0..n as u32).collect()),
                };
                let mut batcher = SageBatcher::new(task, &model, 9)?;
                // Pre-produce the batches so the measurement isolates the
                // train step itself from sampling/gather time.
                let batches: Vec<_> = (0..steps).map(|s| batcher.next_batch(s)).collect();
                let mut store = ParamStore::init(&model.manifest, 1);
                let mut losses = Vec::with_capacity(batches.len());
                for b in &batches {
                    losses.push(train::run_step(&model, &mut store, b)?);
                }
                Ok(losses)
            };
            let mut losses = Vec::new();
            let s = Samples::collect(reps, || {
                losses = run_once().expect("bench step");
            });
            let secs_per_step = s.median() / steps as f64;
            t.row(vec![
                batch.to_string(),
                threads.to_string(),
                format!("{:.2}", 1.0 / secs_per_step),
                format!("{:.0}", secs_per_step * 1e9),
            ]);
            rows.push(Json::obj(vec![
                ("batch", Json::num(batch as f64)),
                ("threads", Json::num(threads as f64)),
                ("steps_per_s", Json::num(1.0 / secs_per_step)),
                ("ns_per_step", Json::num(secs_per_step * 1e9)),
            ]));
            let bits: Vec<u32> = losses.iter().map(|l| l.to_bits()).collect();
            match &reference_losses {
                None => reference_losses = Some(bits),
                Some(r) => {
                    if *r != bits {
                        determinism_ok = false;
                    }
                }
            }
        }
    }
    // Full-batch sparse path: GCN + GIN step time, node-count × thread
    // sweep (the whole step — decoder, CSR SpMM propagation, masked CE,
    // backward, AdamW — with no dense n×n anywhere).
    let mut tfb = Table::new(
        "native full-batch train step over sparse CSR (steps/s)",
        &["model", "nodes", "threads", "steps/s", "ns/step"],
    );
    let mut fb_rows: Vec<Json> = Vec::new();
    let fb_nodes: Vec<usize> =
        if bench_util::quick() { vec![500] } else { vec![1000, 4000] };
    for kind in [GnnKind::Gcn, GnnKind::Gin] {
        for &nn in &fb_nodes {
            let build = FullBatchBuild {
                name: format!("bench_fb_{}_{nn}", kind.as_str()),
                gnn: kind,
                coded: true,
                link: false,
                n: nn,
                n_classes: 8,
                d_e: 32,
                hidden: 32,
                c: 16,
                m: 16,
                d_c: 64,
                d_m: 64,
                l: 2,
                light: false,
                e_train: 256,
                e_pred: 512,
                optim: OptimCfg::adamw_gnn(),
            };
            let manifest = build.manifest();
            let fg = sbm(SbmCfg::new(nn, 8, 12.0, 2.0), 5)?;
            let fb_codes = lsh::encode(fg.adj(), CodingCfg::new(16, 16)?, Threshold::Median, 7)?;
            let ids: Vec<u32> = (0..nn as u32).collect();
            let mut buf = Vec::new();
            fb_codes.gather_int_codes(&ids, &mut buf);
            let batch = vec![
                Tensor::i32(vec![nn, 16], buf)?,
                Tensor::i32(vec![nn], fg.labels().unwrap().iter().map(|&l| l as i32).collect())?,
                Tensor::f32(vec![nn], vec![1.0; nn])?,
            ];
            let adj = Arc::new(fg.adj().normalized(manifest.hyper_str("adj")?)?);
            let mut reference: Option<Vec<u32>> = None;
            for &threads in &thread_counts {
                let model = Model::native(manifest.clone(), threads)?;
                model.bind_adjacency(adj.clone())?;
                let mut losses: Vec<f32> = Vec::new();
                let s = Samples::collect(reps, || {
                    let mut store = ParamStore::init(&model.manifest, 1);
                    losses.clear();
                    for _ in 0..steps {
                        losses.push(train::run_step(&model, &mut store, &batch).expect("fb step"));
                    }
                });
                let secs_per_step = s.median() / steps as f64;
                tfb.row(vec![
                    kind.as_str().into(),
                    nn.to_string(),
                    threads.to_string(),
                    format!("{:.2}", 1.0 / secs_per_step),
                    format!("{:.0}", secs_per_step * 1e9),
                ]);
                fb_rows.push(Json::obj(vec![
                    ("model", Json::str(kind.as_str())),
                    ("n_nodes", Json::num(nn as f64)),
                    ("threads", Json::num(threads as f64)),
                    ("steps_per_s", Json::num(1.0 / secs_per_step)),
                    ("ns_per_step", Json::num(secs_per_step * 1e9)),
                ]));
                let bits: Vec<u32> = losses.iter().map(|l| l.to_bits()).collect();
                match &reference {
                    None => reference = Some(bits),
                    Some(r) => {
                        if *r != bits {
                            determinism_ok = false;
                        }
                    }
                }
            }
        }
    }

    // Inference/serving path: `ServeSession::embed_nodes` throughput —
    // per miss: per-node fan-out sample + code decode + 2-layer SAGE
    // encode in pool-sized batches; per hit: exact-LRU replay. Sweeps
    // batch size × threads × cache-hit rate, and feeds the same
    // determinism assert (served bytes bit-identical across threads).
    let mut ti = Table::new(
        "serve embed_nodes (nodes/s; bit-identical across threads)",
        &["batch", "threads", "hit rate", "nodes/s", "us/node"],
    );
    let mut infer_rows: Vec<Json> = Vec::new();
    let q = bench_util::pick(512usize, 128);
    let ids: Vec<u32> = (0..q).map(|i| (i * (n / q)) as u32).collect();
    let edges = g.undirected_edges();
    for batch in [64usize, 256] {
        let manifest = build_for(batch, n).manifest();
        let store = ParamStore::init(&manifest, 1);
        let bundle =
            ServingBundle::new(manifest, &store, Some((*codes).clone()), edges.clone(), n)?;
        let mut reference: Option<Vec<u32>> = None;
        for &threads in &thread_counts {
            for hit in [0.0f64, 0.5, 1.0] {
                let mut secs = Vec::with_capacity(reps);
                let mut first_bytes: Vec<u32> = Vec::new();
                for _ in 0..reps {
                    // Fresh session per rep so the measured pass sees
                    // exactly the configured hit rate (prewarm untimed).
                    let mut session = ServeSession::new(
                        bundle.clone(),
                        ServeOpts { threads, cache_capacity: 2 * q, seed: 11, ..Default::default() },
                    )?;
                    let warm = (hit * q as f64).round() as usize;
                    if warm > 0 {
                        session.embed_nodes(&ids[..warm])?;
                    }
                    let (out, dt) = bench_util::timed(|| session.embed_nodes(&ids));
                    let out = out?;
                    secs.push(dt);
                    if first_bytes.is_empty() {
                        first_bytes = out.iter().map(|v| v.to_bits()).collect();
                    }
                }
                secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let sec = secs[secs.len() / 2];
                let nodes_per_s = q as f64 / sec;
                ti.row(vec![
                    batch.to_string(),
                    threads.to_string(),
                    format!("{:.0}%", hit * 100.0),
                    format!("{nodes_per_s:.0}"),
                    format!("{:.1}", sec / q as f64 * 1e6),
                ]);
                infer_rows.push(Json::obj(vec![
                    ("batch", Json::num(batch as f64)),
                    ("threads", Json::num(threads as f64)),
                    ("cache_hit_rate", Json::num(hit)),
                    ("nodes_per_s", Json::num(nodes_per_s)),
                    ("us_per_node", Json::num(sec / q as f64 * 1e6)),
                ]));
                if hit == 0.0 {
                    match &reference {
                        None => reference = Some(first_bytes),
                        Some(r) => {
                            if *r != first_bytes {
                                determinism_ok = false;
                            }
                        }
                    }
                }
            }
        }
    }
    println!("{}", ti.render());

    // Before/after: sequential single-stream sampling vs the pooled
    // per-position seed-stream sampler. threads == 1 IS the sequential
    // reference (`sample_streams_par` falls back to `sample_streams`);
    // every pooled row's output is asserted bit-equal to it.
    let mut tsmp = Table::new(
        "pooled neighbor sampling (bit-identical to sequential reference)",
        &["mode", "threads", "batches/s", "us/batch", "speedup"],
    );
    let mut sampler_rows: Vec<Json> = Vec::new();
    {
        let sampler = NeighborSampler::new(&g, 5, 5);
        let sbatch: Vec<u32> = (0..256).map(|i| (i * (n / 256)) as u32).collect();
        let sreps = bench_util::pick(100usize, 20);
        let reference = sampler.sample_streams(&sbatch, 0xBEEF);
        let mut seq_secs: Option<f64> = None;
        for &threads in &thread_counts {
            let mode = if threads == 1 { "sequential" } else { "pooled" };
            let sample = sampler.sample_streams_par(&sbatch, 0xBEEF, threads);
            if sample.hop1 != reference.hop1 || sample.hop2 != reference.hop2 {
                determinism_ok = false;
            }
            let s = Samples::collect(reps, || {
                for _ in 0..sreps {
                    std::hint::black_box(sampler.sample_streams_par(&sbatch, 0xBEEF, threads));
                }
            });
            let secs = s.median() / sreps as f64;
            let base = *seq_secs.get_or_insert(secs);
            tsmp.row(vec![
                mode.into(),
                threads.to_string(),
                format!("{:.0}", 1.0 / secs),
                format!("{:.1}", secs * 1e6),
                format!("{:.2}x", base / secs),
            ]);
            sampler_rows.push(Json::obj(vec![
                ("mode", Json::str(mode)),
                ("threads", Json::num(threads as f64)),
                ("batch", Json::num(sbatch.len() as f64)),
                ("batches_per_s", Json::num(1.0 / secs)),
                ("us_per_batch", Json::num(secs * 1e6)),
                ("speedup_vs_sequential", Json::num(base / secs)),
            ]));
        }
    }
    println!("{}", tsmp.render());

    // Before/after: fresh-alloc steps vs step-scratch reuse, on
    // pre-produced batches so only step execution is measured. The loss
    // bits of both modes must match — reuse is structurally a zero-fill.
    let mut tscr = Table::new(
        "step-scratch reuse (bit-identical to fresh alloc)",
        &["mode", "threads", "steps/s", "ns/step"],
    );
    let mut scratch_rows: Vec<Json> = Vec::new();
    {
        let manifest = build_for(128, n).manifest();
        let probe = Model::native(manifest.clone(), 1)?;
        let task = SageTask {
            graph: g.clone(),
            labels: labels.clone(),
            features: Features::Codes(codes.clone()),
            train_nodes: Arc::new((0..n as u32).collect()),
        };
        let mut batcher = SageBatcher::new(task, &probe, 9)?;
        let batches: Vec<_> = (0..steps).map(|s| batcher.next_batch(s)).collect();
        let mut reference: Option<Vec<u32>> = None;
        for &threads in &thread_counts {
            for (mode, reuse) in [("fresh_alloc", false), ("scratch_reuse", true)] {
                let model = Model::native(manifest.clone(), threads)?;
                model.set_scratch_reuse(reuse)?;
                let mut losses: Vec<f32> = Vec::new();
                let s = Samples::collect(reps, || {
                    let mut store = ParamStore::init(&model.manifest, 1);
                    losses.clear();
                    for b in &batches {
                        losses.push(train::run_step(&model, &mut store, b).expect("scratch step"));
                    }
                });
                let secs_per_step = s.median() / steps as f64;
                tscr.row(vec![
                    mode.into(),
                    threads.to_string(),
                    format!("{:.2}", 1.0 / secs_per_step),
                    format!("{:.0}", secs_per_step * 1e9),
                ]);
                scratch_rows.push(Json::obj(vec![
                    ("mode", Json::str(mode)),
                    ("threads", Json::num(threads as f64)),
                    ("steps_per_s", Json::num(1.0 / secs_per_step)),
                    ("ns_per_step", Json::num(secs_per_step * 1e9)),
                ]));
                let bits: Vec<u32> = losses.iter().map(|l| l.to_bits()).collect();
                match &reference {
                    None => reference = Some(bits),
                    Some(r) => {
                        if *r != bits {
                            determinism_ok = false;
                        }
                    }
                }
            }
        }
    }
    println!("{}", tscr.render());

    // Pipeline depth sweep: serial reference vs pipelined producer at
    // prefetch {1, 2, 4}, end-to-end (sampling + step). Loss curves must
    // be bit-identical — depth only moves where time is spent.
    let mut tpipe = Table::new(
        "pipelined training end-to-end (loss bit-identical across depths)",
        &["mode", "prefetch", "sample threads", "steps/s"],
    );
    let mut pipeline_rows: Vec<Json> = Vec::new();
    {
        let manifest = build_for(128, n).manifest();
        let model = Model::native(manifest.clone(), avail)?;
        let psteps = bench_util::pick(24u64, 6);
        let sample_threads = avail.min(4);
        let mut configs: Vec<(&str, bool, usize, usize)> = vec![("serial", false, 1, 1)];
        for &pf in &[1usize, 2, 4] {
            configs.push(("pipelined", true, pf, sample_threads));
        }
        let mut reference: Option<Vec<u32>> = None;
        for (mode, pipeline, prefetch, st) in configs {
            let mut secs = Vec::with_capacity(reps);
            let mut bits: Vec<u32> = Vec::new();
            for _ in 0..reps {
                let batcher = SageBatcher::new(
                    SageTask {
                        graph: g.clone(),
                        labels: labels.clone(),
                        features: Features::Codes(codes.clone()),
                        train_nodes: Arc::new((0..n as u32).collect()),
                    },
                    &model,
                    9,
                )?
                .with_sample_threads(st);
                let mut opts = TrainOpts::new(psteps);
                opts.pipeline = pipeline;
                opts.prefetch = prefetch;
                let mut store = ParamStore::init(&model.manifest, 1);
                let (log, dt) = bench_util::timed(|| train::train(&model, &mut store, batcher, opts));
                let log = log?;
                secs.push(dt);
                if bits.is_empty() {
                    bits = log.losses.iter().map(|l| l.to_bits()).collect();
                }
            }
            secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let sec = secs[secs.len() / 2];
            let steps_per_s = psteps as f64 / sec;
            tpipe.row(vec![
                mode.into(),
                prefetch.to_string(),
                st.to_string(),
                format!("{steps_per_s:.2}"),
            ]);
            pipeline_rows.push(Json::obj(vec![
                ("mode", Json::str(mode)),
                ("prefetch", Json::num(prefetch as f64)),
                ("sample_threads", Json::num(st as f64)),
                ("steps_per_s", Json::num(steps_per_s)),
            ]));
            match &reference {
                None => reference = Some(bits),
                Some(r) => {
                    if *r != bits {
                        determinism_ok = false;
                    }
                }
            }
        }
    }
    println!("{}", tpipe.render());

    assert!(determinism_ok, "native train step diverged across thread counts");
    t.row(vec![
        "determinism (loss bits across thread counts)".into(),
        "-".into(),
        determinism_ok.to_string(),
        "-".into(),
    ]);
    println!("{}", t.render());
    println!("{}", tfb.render());

    let json = Json::obj(vec![
        ("bench", Json::str("train_step")),
        ("backend", Json::str("native")),
        ("quick", Json::Bool(bench_util::quick())),
        ("n_nodes", Json::num(n as f64)),
        ("steps_timed", Json::num(steps as f64)),
        ("available_parallelism", Json::num(avail as f64)),
        ("loss_bit_identical_across_threads", Json::Bool(determinism_ok)),
        ("rows", Json::Arr(rows)),
        ("rows_fullbatch", Json::Arr(fb_rows)),
        ("rows_infer", Json::Arr(infer_rows)),
        ("rows_sampler", Json::Arr(sampler_rows)),
        ("rows_scratch", Json::Arr(scratch_rows)),
        ("rows_pipeline", Json::Arr(pipeline_rows)),
    ]);
    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_default()
        .join("BENCH_train_step.json");
    ser::to_file(&out_path, &json)?;
    eprintln!("wrote {}", out_path.display());
    Ok(())
}
