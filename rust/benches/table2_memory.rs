//! Table 2 — memory breakdown (MiB) on ogbn-products at paper scale,
//! plus a measured breakdown at this repo's artifact scale.

mod bench_util;

use hashgnn::cfg::CodingCfg;
use hashgnn::params::ParamStore;
use hashgnn::report::Table;
use hashgnn::runtime::Engine;
use hashgnn::tasks::memory;

fn render(rows: &[memory::MemoryRow], title: &str) {
    let mut t = Table::new(
        title,
        &[
            "Method", "CPU code", "CPU dec", "CPU tot", "GPU model", "GPU gnn", "GPU tot",
            "GPU ratio", "Total", "Ratio",
        ],
    );
    for r in rows {
        t.row(vec![
            r.method.clone(),
            format!("{:.2}", r.cpu_code),
            format!("{:.2}", r.cpu_decoder),
            format!("{:.2}", r.cpu_total),
            format!("{:.2}", r.gpu_model),
            format!("{:.2}", r.gpu_gnn),
            format!("{:.2}", r.gpu_total),
            format!("{:.2}", r.gpu_ratio),
            format!("{:.2}", r.total),
            format!("{:.2}", r.total_ratio),
        ]);
    }
    println!("{}", t.render());
}

fn main() -> hashgnn::Result<()> {
    bench_util::banner("table2_memory", "Table 2 (memory cost breakdown)");
    // Paper scale: ogbn-products, n = 1,871,031, d_e = 64, (c=256, m=16),
    // d_c = d_m = 512. Expected: 456.79 / 28.55 / 8.00 / 1.13 / 9.13 MiB,
    // ratios 43.75 (GPU) and 11.74 (total).
    let rows = memory::table2(
        1_871_031,
        64,
        CodingCfg::new(256, 16)?,
        512,
        512,
        (1.35 * memory::MIB) as usize,
    );
    render(&rows, "Table 2 @ paper scale (ogbn-products, analytic)");

    // Measured at this repo's artifact scale: actual ParamStore bytes of
    // the exported merchant model vs a hypothetical raw table.
    let engine = Engine::cpu("artifacts")?;
    if let Ok(model) = engine.load("merchant") {
        let store = ParamStore::init(&model.manifest, 1);
        let n = model.manifest.hyper_usize("n")?;
        let d_e = model.manifest.hyper_usize("d_e")?;
        let c = model.manifest.hyper_usize("c")?;
        let m = model.manifest.hyper_usize("m")?;
        let coding = CodingCfg::new(c, m)?;
        let mut t = Table::new(
            "Measured @ artifact scale (merchant model)",
            &["quantity", "MiB"],
        );
        t.row(vec![
            format!("raw table would be (n={n}, d_e={d_e})"),
            format!("{:.2}", memory::raw_bytes(n, d_e) as f64 / memory::MIB),
        ]);
        t.row(vec![
            "bit-packed codes".into(),
            format!("{:.2}", memory::code_bytes(n, coding) as f64 / memory::MIB),
        ]);
        t.row(vec![
            "decoder+GNN params (measured ParamStore)".into(),
            format!("{:.2}", store.param_bytes() as f64 / memory::MIB),
        ]);
        println!("{}", t.render());
    } else {
        eprintln!("(artifacts not built; measured section skipped)");
    }
    Ok(())
}
