//! Table 2 — memory breakdown (MiB) on ogbn-products at paper scale,
//! plus a measured breakdown at this repo's artifact scale and the
//! serving-side bytes-resident before/after rows (legacy v1 envelope
//! copies every section to the heap; the v2 section table serves views
//! of one backing buffer, and int8 quantization shrinks the file ~4×
//! on the parameter sections).

mod bench_util;

use hashgnn::cfg::{Coder, CodingCfg};
use hashgnn::params::ParamStore;
use hashgnn::report::Table;
use hashgnn::runtime::native::spec;
use hashgnn::runtime::Engine;
use hashgnn::serve::{Quant, ServingBundle};
use hashgnn::tasks::memory;
use hashgnn::tasks::serve::{export_bundle, ExportOpts};

fn render(rows: &[memory::MemoryRow], title: &str) {
    let mut t = Table::new(
        title,
        &[
            "Method", "CPU code", "CPU dec", "CPU tot", "GPU model", "GPU gnn", "GPU tot",
            "GPU ratio", "Total", "Ratio",
        ],
    );
    for r in rows {
        t.row(vec![
            r.method.clone(),
            format!("{:.2}", r.cpu_code),
            format!("{:.2}", r.cpu_decoder),
            format!("{:.2}", r.cpu_total),
            format!("{:.2}", r.gpu_model),
            format!("{:.2}", r.gpu_gnn),
            format!("{:.2}", r.gpu_total),
            format!("{:.2}", r.gpu_ratio),
            format!("{:.2}", r.total),
            format!("{:.2}", r.total_ratio),
        ]);
    }
    println!("{}", t.render());
}

fn main() -> hashgnn::Result<()> {
    bench_util::banner("table2_memory", "Table 2 (memory cost breakdown)");
    // Paper scale: ogbn-products, n = 1,871,031, d_e = 64, (c=256, m=16),
    // d_c = d_m = 512. Expected: 456.79 / 28.55 / 8.00 / 1.13 / 9.13 MiB,
    // ratios 43.75 (GPU) and 11.74 (total).
    let rows = memory::table2(
        1_871_031,
        64,
        CodingCfg::new(256, 16)?,
        512,
        512,
        (1.35 * memory::MIB) as usize,
    );
    render(&rows, "Table 2 @ paper scale (ogbn-products, analytic)");

    // Measured at this repo's artifact scale: actual ParamStore bytes of
    // the exported merchant model vs a hypothetical raw table.
    let engine = Engine::cpu("artifacts")?;
    if let Ok(model) = engine.load("merchant") {
        let store = ParamStore::init(&model.manifest, 1);
        let n = model.manifest.hyper_usize("n")?;
        let d_e = model.manifest.hyper_usize("d_e")?;
        let c = model.manifest.hyper_usize("c")?;
        let m = model.manifest.hyper_usize("m")?;
        let coding = CodingCfg::new(c, m)?;
        let mut t = Table::new(
            "Measured @ artifact scale (merchant model)",
            &["quantity", "MiB"],
        );
        t.row(vec![
            format!("raw table would be (n={n}, d_e={d_e})"),
            format!("{:.2}", memory::raw_bytes(n, d_e) as f64 / memory::MIB),
        ]);
        t.row(vec![
            "bit-packed codes".into(),
            format!("{:.2}", memory::code_bytes(n, coding) as f64 / memory::MIB),
        ]);
        t.row(vec![
            "decoder+GNN params (measured ParamStore)".into(),
            format!("{:.2}", store.param_bytes() as f64 / memory::MIB),
        ]);
        println!("{}", t.render());
    } else {
        eprintln!("(artifacts not built; measured section skipped)");
    }

    // Serving bytes resident: the same exported bundle written as the
    // legacy v1 envelope, the v2 section table, and v2 with int8 params.
    // "Copied at load" is what the parse path materialises into fresh
    // heap allocations — the whole payload for v1, nothing for v2 f32
    // (borrowed views), and only the dequantized params for int8.
    let manifest = spec::builtin("node_fb_sgc_coded")?;
    let store = ParamStore::init(&manifest, 7);
    let opts = ExportOpts {
        coder: Coder::Hash,
        codes_file: None,
        seed: 7,
        quant: Quant::F32,
        legacy_v1: false,
    };
    let bundle = export_bundle(&manifest, &store, &opts)?;
    let dir = std::env::temp_dir().join("hashgnn_bench_table2");
    std::fs::create_dir_all(&dir).map_err(hashgnn::Error::Io)?;
    let mut t = Table::new(
        "Serving bundle bytes resident (node_fb_sgc_coded, n=1024)",
        &["format", "file KiB", "payload KiB copied at load"],
    );
    for (label, quant, legacy) in [
        ("v1 envelope (before)", Quant::F32, true),
        ("v2 sections (after)", Quant::F32, false),
        ("v2 sections + int8", Quant::Int8, false),
    ] {
        let path = dir.join(format!("t2.{}.bundle", if legacy { "v1" } else { "v2" }));
        if legacy {
            bundle.save_legacy_v1(&path)?;
        } else {
            bundle.save_with(&path, quant)?;
        }
        let file_bytes = std::fs::metadata(&path).map_err(hashgnn::Error::Io)?.len();
        let loaded = ServingBundle::load(&path)?;
        let copied = if loaded.meta.zero_copy {
            0
        } else if loaded.meta.quantized {
            loaded.param_bytes() as u64
        } else {
            file_bytes
        };
        t.row(vec![
            label.into(),
            format!("{:.1}", file_bytes as f64 / 1024.0),
            format!("{:.1}", copied as f64 / 1024.0),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
