//! Table 1 — NC / Rand / Hash across four GNNs on five OGB-analog
//! datasets (3 node classification + 2 link prediction).
//!
//! Expected shape: Hash ≥ Rand almost everywhere; Hash close to (and
//! occasionally above) NC.

mod bench_util;

use hashgnn::cfg::GnnKind;
use hashgnn::report::Table;
use hashgnn::runtime::Engine;
use hashgnn::tasks::nodeclf::{self, Frontend, RunOpts};
use hashgnn::tasks::{linkpred, T1Dataset};

fn main() -> hashgnn::Result<()> {
    bench_util::banner("table1_gnn", "Table 1 (full NC/Rand/Hash × GNN × dataset grid)");
    let engine = Engine::cpu("artifacts")?;
    let opts = RunOpts {
        epochs: bench_util::pick(80, 8),
        eval_every: bench_util::pick(10, 4),
        seed: 7,
    };
    let gnns: Vec<GnnKind> = if bench_util::quick() {
        vec![GnnKind::Gcn]
    } else {
        GnnKind::all().to_vec()
    };

    for gnn in &gnns {
        let mut t = Table::new(
            &format!("Table 1 — {} (test metric @ best val)", gnn.as_str().to_uppercase()),
            &["task", "dataset", "NC", "Rand", "Hash"],
        );
        for ds in T1Dataset::nodeclf_all() {
            let graph = ds.generate(11)?;
            let mut cells = Vec::new();
            for fe in Frontend::all() {
                let (out, secs) =
                    bench_util::timed(|| nodeclf::run_fullbatch(&engine, *gnn, fe, &graph, opts));
                let out = out?;
                eprintln!(
                    "  [{:>4}] {} {} {}: val {:.4} test {:.4} ({secs:.1}s)",
                    gnn.as_str(),
                    ds.name(),
                    fe.name(),
                    "nodeclf",
                    out.val,
                    out.test
                );
                cells.push(format!("{:.4}", out.test));
            }
            t.row(vec![
                "node classification".into(),
                format!("{} (acc.)", ds.name()),
                cells[0].clone(),
                cells[1].clone(),
                cells[2].clone(),
            ]);
        }
        for ds in T1Dataset::linkpred_all() {
            let graph = ds.generate(13)?;
            let hits_k = if ds == T1Dataset::Collab { 50 } else { 20 };
            let mut cells = Vec::new();
            for fe in Frontend::all() {
                let (out, secs) = bench_util::timed(|| {
                    linkpred::run_fullbatch(&engine, *gnn, fe, &graph, hits_k, opts)
                });
                let out = out?;
                eprintln!(
                    "  [{:>4}] {} {} linkpred: val {:.4} test {:.4} ({secs:.1}s)",
                    gnn.as_str(),
                    ds.name(),
                    fe.name(),
                    out.val_hits,
                    out.test_hits
                );
                cells.push(format!("{:.4}", out.test_hits));
            }
            t.row(vec![
                "link prediction".into(),
                format!("{} (hits@{hits_k})", ds.name()),
                cells[0].clone(),
                cells[1].clone(),
                cells[2].clone(),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}
