//! Shared helpers for the bench harnesses (criterion is not available in
//! the offline crate set; these binaries use `harness = false` and print
//! the paper-shaped tables directly).
//!
//! `HASHGNN_QUICK=1` (or passing `--quick` to the bench binary, e.g.
//! `cargo bench --bench perf_hotpath -- --quick`) shrinks every sweep
//! for smoke runs; the default settings regenerate the full
//! table/figure shapes.

#![allow(dead_code)]

use std::time::Instant;

/// True when `HASHGNN_QUICK=1` or `--quick` was passed (CI / smoke mode).
pub fn quick() -> bool {
    if std::env::args().any(|a| a == "--quick") {
        return true;
    }
    std::env::var("HASHGNN_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Pick between a full and a quick value.
pub fn pick<T>(full: T, q: T) -> T {
    if quick() {
        q
    } else {
        full
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Simple timing statistics over repeated runs (median/min reported,
/// which is what criterion's point estimates approximate).
pub struct Samples {
    secs: Vec<f64>,
}

impl Samples {
    pub fn collect(reps: usize, mut f: impl FnMut()) -> Self {
        let mut secs = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            f();
            secs.push(t0.elapsed().as_secs_f64());
        }
        Self { secs }
    }

    pub fn median(&self) -> f64 {
        let mut s = self.secs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    }

    pub fn min(&self) -> f64 {
        self.secs.iter().copied().fold(f64::MAX, f64::min)
    }

    pub fn mean(&self) -> f64 {
        self.secs.iter().sum::<f64>() / self.secs.len() as f64
    }
}

/// Standard bench banner.
pub fn banner(name: &str, what: &str) {
    eprintln!("\n=== bench: {name} ===");
    eprintln!("    regenerates: {what}");
    eprintln!("    mode: {}", if quick() { "QUICK (HASHGNN_QUICK=1)" } else { "full" });
}
