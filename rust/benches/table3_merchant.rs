//! Table 3 — merchant-category identification: Rand vs Hash coding on
//! the synthetic transaction graph (§5.3 analog).
//!
//! Expected shape: Hash beats Rand on every metric by a mild margin
//! (the paper reports +10% acc, +2–4% hit@k).

mod bench_util;

use hashgnn::cfg::Coder;
use hashgnn::report::Table;
use hashgnn::runtime::Engine;
use hashgnn::tasks::merchant;

fn main() -> hashgnn::Result<()> {
    bench_util::banner("table3_merchant", "Table 3 (merchant category identification)");
    let engine = Engine::cpu("artifacts")?;
    let model = engine.load("merchant")?;
    let epochs = bench_util::pick(4, 1);
    let seed = 11u64;

    let (bip, secs) = bench_util::timed(|| merchant::build_graph(&model, seed));
    let bip = bip?;
    eprintln!(
        "  graph: {} consumers, {} merchants, {} categories ({secs:.1}s)",
        bip.n_consumers, bip.n_merchants, bip.n_categories
    );

    let mut rows = Vec::new();
    for coder in [Coder::Random, Coder::Hash] {
        let (out, secs) = bench_util::timed(|| merchant::run(&engine, &bip, coder, epochs, seed));
        let out = out?;
        eprintln!(
            "  {}: acc {:.4} hit@5 {:.4} ({secs:.1}s)",
            coder.as_str(),
            out.metrics.accuracy,
            out.metrics.hit5
        );
        rows.push(out);
    }

    let mut t = Table::new(
        "Table 3 — merchant category identification",
        &["Method", "acc.", "hit@5", "hit@10", "hit@20"],
    );
    for out in &rows {
        let m = &out.metrics;
        t.row(vec![
            match out.coder {
                Coder::Random => "Rand".into(),
                Coder::Hash => "Hash".into(),
                Coder::Learned => "Learn".into(),
            },
            format!("{:.4}", m.accuracy),
            format!("{:.4}", m.hit5),
            format!("{:.4}", m.hit10),
            format!("{:.4}", m.hit20),
        ]);
    }
    let (r, h) = (&rows[0].metrics, &rows[1].metrics);
    t.row(vec![
        "% improve".into(),
        format!("{:.2}%", 100.0 * (h.accuracy - r.accuracy) / r.accuracy.max(1e-9)),
        format!("{:.2}%", 100.0 * (h.hit5 - r.hit5) / r.hit5.max(1e-9)),
        format!("{:.2}%", 100.0 * (h.hit10 - r.hit10) / r.hit10.max(1e-9)),
        format!("{:.2}%", 100.0 * (h.hit20 - r.hit20) / r.hit20.max(1e-9)),
    ]);
    println!("{}", t.render());
    Ok(())
}
