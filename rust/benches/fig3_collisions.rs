//! Figures 3 & 6 — collision counts: median vs zero threshold, repeated
//! trials, 24- and 32-bit codes, on all three embedding-set analogs.
//!
//! Expected shape: the median threshold's histogram sits strictly left of
//! (fewer collisions than) the zero threshold's.

mod bench_util;

use hashgnn::embed::{analogy_embeddings, gaussian_mixture};
use hashgnn::report::{histogram, Table};
use hashgnn::tasks::collisions;

fn main() {
    bench_util::banner("fig3_collisions", "Figures 3 and 6 (collision histograms)");
    let n = bench_util::pick(20000, 4000);
    let trials = bench_util::pick(100, 10);

    let m2v = gaussian_mixture(n, 128, 8, 0.25, 9);
    let m2vpp = gaussian_mixture(n, 128, 8, 0.20, 10);
    let glove = analogy_embeddings(n, 128, 14, 20, 100, 0.05, 5).set;

    let mut summary = Table::new(
        "Fig 3/6 summary — avg collisions over trials",
        &["dataset", "bits", "median", "zero"],
    );
    for (name, set) in [("metapath2vec*", &m2v), ("metapath2vec++*", &m2vpp), ("GloVe*", &glove)]
    {
        for bits in [24usize, 32] {
            // Figure 3 runs both bit settings for m2v; Figure 6 runs 24
            // bits for the other two — we run both everywhere.
            let r = collisions::run(name, set, bits, trials, 100);
            println!("{}", histogram(&format!("{name} {bits}-bit, median threshold"), &r.median, 8));
            println!("{}", histogram(&format!("{name} {bits}-bit, zero threshold"), &r.zero, 8));
            summary.row(vec![
                name.into(),
                bits.to_string(),
                format!("{:.1}", r.median_avg()),
                format!("{:.1}", r.zero_avg()),
            ]);
        }
    }
    println!("{}", summary.render());
}
