//! §Perf — hot-path microbenchmarks (EXPERIMENTS.md §Perf before/after
//! numbers come from here).
//!
//! L3 paths: Algorithm-1 encode (bit-by-bit reference vs blocked vs the
//! multi-threaded engine with 1/2/all-core scaling rows), median
//! (quickselect vs full sort), code gathering, neighbor sampling, and the
//! end-to-end train step with the batch pipeline on vs off.
//!
//! Besides the stdout table, writes machine-readable
//! `BENCH_perf_hotpath.json` at the repo root so the perf trajectory is
//! tracked across PRs. Also asserts the encode engine's determinism
//! contract (bit-identical output across thread counts) on every run.

mod bench_util;

use std::sync::Arc;

use bench_util::Samples;
use hashgnn::cfg::{CodingCfg, EncodeCfg};
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::graph::NeighborSampler;
use hashgnn::lsh::{self, median_in_place, Threshold};
use hashgnn::params::ParamStore;
use hashgnn::report::Table;
use hashgnn::rng::{Rng, Xoshiro256pp};
use hashgnn::runtime::Engine;
use hashgnn::ser::{self, Json};
use hashgnn::tasks::sage::{self, Features, SageTask};
use hashgnn::train::{self, TrainOpts};

fn main() -> hashgnn::Result<()> {
    bench_util::banner("perf_hotpath", "§Perf microbenches (EXPERIMENTS.md)");
    let mut t = Table::new("hot-path microbenchmarks", &["path", "metric", "value"]);
    let mut json_rows: Vec<Json> = Vec::new();
    let n = bench_util::pick(20000, 5000);
    let reps = bench_util::pick(5, 2);

    fn push_row(t: &mut Table, json_rows: &mut Vec<Json>, path: &str, metric: &str, value: f64) {
        t.row(vec![path.into(), metric.into(), format!("{value:.1}")]);
        json_rows.push(Json::obj(vec![
            ("path", Json::str(path)),
            ("metric", Json::str(metric)),
            ("value", Json::num(value)),
        ]));
    }

    // ---- L3: LSH encode -------------------------------------------------
    let g = sbm(SbmCfg::new(n, 8, 12.0, 2.0), 3)?;
    let coding = CodingCfg::new(16, 32)?; // 128 bits
    let s = Samples::collect(reps, || {
        let _ = lsh::encode(g.adj(), coding, Threshold::Median, 7).unwrap();
    });
    let bitbybit_rate = n as f64 / s.median();
    push_row(&mut t, &mut json_rows, "lsh::encode (bit-by-bit reference)", "nodes/s", bitbybit_rate);
    for block in [8usize, 32] {
        let s = Samples::collect(reps, || {
            let _ = lsh::encode_blocked(g.adj(), coding, Threshold::Median, 7, block).unwrap();
        });
        push_row(
            &mut t,
            &mut json_rows,
            &format!("lsh::encode_blocked (B={block}, 1 thread)"),
            "nodes/s",
            n as f64 / s.median(),
        );
    }

    // ---- L3: parallel encode engine (thread-scaling rows) ---------------
    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut thread_counts = vec![1usize];
    if avail >= 2 {
        thread_counts.push(2);
    }
    if avail > 2 {
        thread_counts.push(avail);
    }
    let mut engine_rates: Vec<(usize, f64)> = Vec::new();
    for &threads in &thread_counts {
        let plan = EncodeCfg::new(threads, 64);
        let s = Samples::collect(reps, || {
            let _ = lsh::encode_with(g.adj(), coding, Threshold::Median, 7, plan).unwrap();
        });
        let rate = n as f64 / s.median();
        push_row(
            &mut t,
            &mut json_rows,
            &format!("lsh::encode_with (B=64, threads={threads})"),
            "nodes/s",
            rate,
        );
        engine_rates.push((threads, rate));
    }
    // Determinism contract: same bits from the reference path and the
    // engine at full parallelism.
    let reference = lsh::encode(g.adj(), coding, Threshold::Median, 7)?;
    let parallel = lsh::encode_with(g.adj(), coding, Threshold::Median, 7, EncodeCfg::new(avail, 64))?;
    let bit_identical = reference.bits == parallel.bits;
    t.row(vec![
        "encode determinism (reference vs all-thread engine)".into(),
        "bit-identical".into(),
        bit_identical.to_string(),
    ]);
    assert!(bit_identical, "parallel encode diverged from the bit-by-bit reference");
    let engine_best = engine_rates.iter().map(|&(_, r)| r).fold(0.0f64, f64::max);

    // ---- L3: median selection -------------------------------------------
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let base: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let s_qs = Samples::collect(20, || {
        let mut buf = base.clone();
        let _ = median_in_place(&mut buf);
    });
    let s_sort = Samples::collect(20, || {
        let mut buf = base.clone();
        buf.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let _ = buf[(buf.len() - 1) / 2];
    });
    push_row(&mut t, &mut json_rows, "median: quickselect", "Melem/s", n as f64 / s_qs.median() / 1e6);
    push_row(
        &mut t,
        &mut json_rows,
        "median: full sort (baseline)",
        "Melem/s",
        n as f64 / s_sort.median() / 1e6,
    );

    // ---- L3: collision counting -----------------------------------------
    let codes = lsh::encode_with(g.adj(), coding, Threshold::Median, 7, EncodeCfg::default())?;
    let s = Samples::collect(10, || {
        let _ = codes.bits.n_collisions();
    });
    push_row(
        &mut t,
        &mut json_rows,
        "codes::n_collisions (hash+sort)",
        "Mrows/s",
        n as f64 / s.median() / 1e6,
    );

    // ---- L3: code gather + neighbor sampling ----------------------------
    let ids: Vec<u32> = (0..4096u32).map(|i| i % n as u32).collect();
    let mut buf = Vec::new();
    let s = Samples::collect(50, || {
        codes.gather_int_codes(&ids, &mut buf);
    });
    push_row(
        &mut t,
        &mut json_rows,
        "codes::gather_int_codes",
        "Mcodes/s",
        ids.len() as f64 / s.median() / 1e6,
    );
    let sampler = NeighborSampler::new(&g, 10, 10);
    let batch: Vec<u32> = (0..256u32).collect();
    let mut srng = Xoshiro256pp::seed_from_u64(9);
    let s = Samples::collect(50, || {
        let _ = sampler.sample(&batch, &mut srng);
    });
    push_row(
        &mut t,
        &mut json_rows,
        "sampler (B=256, 10x10 fanout)",
        "batches/s",
        1.0 / s.median(),
    );

    // ---- e2e: train step, pipeline on vs off ----------------------------
    // With no artifacts present the Auto backend resolves to the native
    // engine, so this section now always runs offline.
    let engine = Engine::cpu("artifacts")?;
    if let Ok(model) = engine.load("sage_mb_coded") {
        eprintln!("(e2e backend: {})", model.backend_name());
        let nn = model.manifest.hyper_usize("n")?;
        let gg = Arc::new(sbm(SbmCfg::new(nn, 8, 12.0, 2.0), 3)?);
        let labels = Arc::new(gg.labels().unwrap().to_vec());
        let table = Arc::new(lsh::encode_with(
            gg.adj(),
            coding,
            Threshold::Median,
            7,
            EncodeCfg::default(),
        )?);
        let steps = bench_util::pick(20u64, 6);
        for pipeline in [false, true] {
            let task = SageTask {
                graph: gg.clone(),
                labels: labels.clone(),
                features: Features::Codes(table.clone()),
                train_nodes: Arc::new((0..nn as u32).collect()),
            };
            let batcher = sage::SageBatcher::new(task, &model, 9)?;
            let mut store = ParamStore::init(&model.manifest, 1);
            let mut opts = TrainOpts::new(steps);
            opts.pipeline = pipeline;
            let (log, secs) = bench_util::timed(|| train::train(&model, &mut store, batcher, opts));
            let log = log?;
            push_row(
                &mut t,
                &mut json_rows,
                &format!(
                    "sage_mb train step ({}, pipeline={pipeline})",
                    model.backend_name()
                ),
                "steps/s",
                log.losses.len() as f64 / secs,
            );
        }
    } else {
        eprintln!("(model unavailable; e2e section skipped)");
    }

    println!("{}", t.render());

    // ---- machine-readable trajectory file at the repo root ---------------
    let json = Json::obj(vec![
        ("bench", Json::str("perf_hotpath")),
        ("quick", Json::Bool(bench_util::quick())),
        ("n_nodes", Json::num(n as f64)),
        ("encode_n_bits", Json::num(coding.n_bits() as f64)),
        ("available_parallelism", Json::num(avail as f64)),
        ("encode_bit_identical_across_threads", Json::Bool(bit_identical)),
        (
            "encode_speedup_engine_vs_bitbybit",
            Json::num(if bitbybit_rate > 0.0 { engine_best / bitbybit_rate } else { 0.0 }),
        ),
        ("rows", Json::Arr(json_rows)),
    ]);
    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_default()
        .join("BENCH_perf_hotpath.json");
    ser::to_file(&out_path, &json)?;
    eprintln!("wrote {}", out_path.display());
    Ok(())
}
