//! §Perf — hot-path microbenchmarks (EXPERIMENTS.md §Perf before/after
//! numbers come from here).
//!
//! L3 paths: Algorithm-1 encode (bit-by-bit reference vs blocked vs the
//! multi-threaded engine with 1/2/all-core scaling rows), median
//! (quickselect vs full sort), code gathering, neighbor sampling, and the
//! end-to-end train step with the batch pipeline on vs off.
//!
//! Kernel before/after rows (docs/PERFORMANCE.md): scalar-reference vs
//! register-tiled dense matmul, unfused gather→decode→linear vs the
//! fused [`ops::codebook_linear_fwd`] kernel, scalar vs column-tiled CSR
//! SpMM — each pair asserted bit-identical on every run — plus the
//! sharded serving flush walked sequentially vs fanned out in parallel
//! (p50/p99 per-flush latency, bytes asserted identical).
//!
//! Besides the stdout table, writes machine-readable
//! `BENCH_perf_hotpath.json` at the repo root so the perf trajectory is
//! tracked across PRs. Also asserts the encode engine's determinism
//! contract (bit-identical output across thread counts) on every run.

mod bench_util;

use std::sync::Arc;

use bench_util::Samples;
use hashgnn::cfg::{CodingCfg, EncodeCfg, OptimCfg};
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::graph::NeighborSampler;
use hashgnn::lsh::{self, median_in_place, Threshold};
use hashgnn::params::ParamStore;
use hashgnn::report::Table;
use hashgnn::rng::{Rng, Xoshiro256pp};
use hashgnn::runtime::native::ops;
use hashgnn::runtime::native::spec::SageMbBuild;
use hashgnn::runtime::Engine;
use hashgnn::ser::{self, Json};
use hashgnn::serve::{Quant, ServeOpts, ServeSession, ServingBundle, ShardRouter};
use hashgnn::tasks::sage::{self, Features, SageTask};
use hashgnn::train::{self, TrainOpts};

/// Textbook triple-loop matmul with the same ascending-`k` reduction
/// order as the tiled kernel — the "before" reference the tiled rows are
/// compared (and bit-checked) against.
fn scalar_matmul(x: &[f32], w: &[f32], n: usize, d_in: usize, d_out: usize, out: &mut [f32]) {
    for r in 0..n {
        for o in 0..d_out {
            let mut acc = 0.0f32;
            for k in 0..d_in {
                acc += x[r * d_in + k] * w[k * d_out + o];
            }
            out[r * d_out + o] = acc;
        }
    }
}

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Percentile over a sorted sample (nearest-rank on the sorted slice).
fn percentile(sorted: &[f64], p: usize) -> f64 {
    sorted[(sorted.len() - 1) * p / 100]
}

fn main() -> hashgnn::Result<()> {
    bench_util::banner("perf_hotpath", "§Perf microbenches (EXPERIMENTS.md)");
    let mut t = Table::new("hot-path microbenchmarks", &["path", "metric", "value"]);
    let mut json_rows: Vec<Json> = Vec::new();
    let n = bench_util::pick(20000, 5000);
    let reps = bench_util::pick(5, 2);

    fn push_row(t: &mut Table, json_rows: &mut Vec<Json>, path: &str, metric: &str, value: f64) {
        t.row(vec![path.into(), metric.into(), format!("{value:.1}")]);
        json_rows.push(Json::obj(vec![
            ("path", Json::str(path)),
            ("metric", Json::str(metric)),
            ("value", Json::num(value)),
        ]));
    }

    // ---- L3: LSH encode -------------------------------------------------
    let g = sbm(SbmCfg::new(n, 8, 12.0, 2.0), 3)?;
    let coding = CodingCfg::new(16, 32)?; // 128 bits
    let s = Samples::collect(reps, || {
        let _ = lsh::encode(g.adj(), coding, Threshold::Median, 7).unwrap();
    });
    let bitbybit_rate = n as f64 / s.median();
    push_row(&mut t, &mut json_rows, "lsh::encode (bit-by-bit reference)", "nodes/s", bitbybit_rate);
    for block in [8usize, 32] {
        let s = Samples::collect(reps, || {
            let _ = lsh::encode_blocked(g.adj(), coding, Threshold::Median, 7, block).unwrap();
        });
        push_row(
            &mut t,
            &mut json_rows,
            &format!("lsh::encode_blocked (B={block}, 1 thread)"),
            "nodes/s",
            n as f64 / s.median(),
        );
    }

    // ---- L3: parallel encode engine (thread-scaling rows) ---------------
    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut thread_counts = vec![1usize];
    if avail >= 2 {
        thread_counts.push(2);
    }
    if avail > 2 {
        thread_counts.push(avail);
    }
    let mut engine_rates: Vec<(usize, f64)> = Vec::new();
    for &threads in &thread_counts {
        let plan = EncodeCfg::new(threads, 64);
        let s = Samples::collect(reps, || {
            let _ = lsh::encode_with(g.adj(), coding, Threshold::Median, 7, plan).unwrap();
        });
        let rate = n as f64 / s.median();
        push_row(
            &mut t,
            &mut json_rows,
            &format!("lsh::encode_with (B=64, threads={threads})"),
            "nodes/s",
            rate,
        );
        engine_rates.push((threads, rate));
    }
    // Determinism contract: same bits from the reference path and the
    // engine at full parallelism.
    let reference = lsh::encode(g.adj(), coding, Threshold::Median, 7)?;
    let parallel = lsh::encode_with(g.adj(), coding, Threshold::Median, 7, EncodeCfg::new(avail, 64))?;
    let bit_identical = reference.bits == parallel.bits;
    t.row(vec![
        "encode determinism (reference vs all-thread engine)".into(),
        "bit-identical".into(),
        bit_identical.to_string(),
    ]);
    assert!(bit_identical, "parallel encode diverged from the bit-by-bit reference");
    let engine_best = engine_rates.iter().map(|&(_, r)| r).fold(0.0f64, f64::max);

    // ---- L3: median selection -------------------------------------------
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let base: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let s_qs = Samples::collect(20, || {
        let mut buf = base.clone();
        let _ = median_in_place(&mut buf);
    });
    let s_sort = Samples::collect(20, || {
        let mut buf = base.clone();
        buf.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let _ = buf[(buf.len() - 1) / 2];
    });
    push_row(&mut t, &mut json_rows, "median: quickselect", "Melem/s", n as f64 / s_qs.median() / 1e6);
    push_row(
        &mut t,
        &mut json_rows,
        "median: full sort (baseline)",
        "Melem/s",
        n as f64 / s_sort.median() / 1e6,
    );

    // ---- L3: collision counting -----------------------------------------
    let codes = lsh::encode_with(g.adj(), coding, Threshold::Median, 7, EncodeCfg::default())?;
    let s = Samples::collect(10, || {
        let _ = codes.bits.n_collisions();
    });
    push_row(
        &mut t,
        &mut json_rows,
        "codes::n_collisions (hash+sort)",
        "Mrows/s",
        n as f64 / s.median() / 1e6,
    );

    // ---- L3: code gather + neighbor sampling ----------------------------
    let ids: Vec<u32> = (0..4096u32).map(|i| i % n as u32).collect();
    let mut buf = Vec::new();
    let s = Samples::collect(50, || {
        codes.gather_int_codes(&ids, &mut buf);
    });
    push_row(
        &mut t,
        &mut json_rows,
        "codes::gather_int_codes",
        "Mcodes/s",
        ids.len() as f64 / s.median() / 1e6,
    );
    let sampler = NeighborSampler::new(&g, 10, 10);
    let batch: Vec<u32> = (0..256u32).collect();
    let mut srng = Xoshiro256pp::seed_from_u64(9);
    let s = Samples::collect(50, || {
        let _ = sampler.sample(&batch, &mut srng);
    });
    push_row(
        &mut t,
        &mut json_rows,
        "sampler (B=256, 10x10 fanout)",
        "batches/s",
        1.0 / s.median(),
    );

    // ---- L3: dense matmul, scalar reference vs register-tiled -----------
    let (mm_n, d_in, d_out) = (bench_util::pick(1024usize, 256), 128usize, 128usize);
    let mut krng = Xoshiro256pp::seed_from_u64(21);
    let x: Vec<f32> = (0..mm_n * d_in).map(|_| krng.normal() as f32).collect();
    let w: Vec<f32> = (0..d_in * d_out).map(|_| krng.normal() as f32).collect();
    let gflop = (2 * mm_n * d_in * d_out) as f64 / 1e9;
    let mut out_ref = vec![0.0f32; mm_n * d_out];
    let s = Samples::collect(reps, || scalar_matmul(&x, &w, mm_n, d_in, d_out, &mut out_ref));
    push_row(
        &mut t,
        &mut json_rows,
        &format!("matmul {mm_n}x{d_in}x{d_out} (scalar reference)"),
        "GFLOP/s",
        gflop / s.median(),
    );
    let mut out_tiled = vec![0.0f32; mm_n * d_out];
    for &threads in &thread_counts {
        let s = Samples::collect(reps, || {
            ops::matmul_fwd(&x, &w, mm_n, d_in, d_out, &mut out_tiled, threads);
        });
        push_row(
            &mut t,
            &mut json_rows,
            &format!("matmul {mm_n}x{d_in}x{d_out} (tiled, threads={threads})"),
            "GFLOP/s",
            gflop / s.median(),
        );
        assert!(
            bits_equal(&out_ref, &out_tiled),
            "tiled matmul diverged from the scalar reference at threads={threads}"
        );
    }

    // ---- L3: codebook decode, unfused pipeline vs fused kernel ----------
    let (dn, m, c, d_c, d_dec) = (bench_util::pick(8192usize, 2048), 16usize, 64usize, 64usize, 64usize);
    let books: Vec<f32> = (0..m * c * d_c).map(|_| krng.normal() as f32).collect();
    let dcodes: Vec<i32> =
        (0..dn * m).map(|_| (krng.next_u64() % c as u64) as i32).collect();
    let dw: Vec<f32> = (0..d_c * d_dec).map(|_| krng.normal() as f32).collect();
    let db: Vec<f32> = (0..d_dec).map(|_| krng.normal() as f32).collect();
    let mut gathered = vec![0.0f32; dn * d_c];
    let mut out_unfused = vec![0.0f32; dn * d_dec];
    let s = Samples::collect(reps, || {
        ops::codebook_fwd(&books, &dcodes, dn, m, c, d_c, &mut gathered, 1);
        ops::linear_fwd(&gathered, &dw, &db, dn, d_c, d_dec, true, &mut out_unfused, 1);
    });
    push_row(
        &mut t,
        &mut json_rows,
        &format!("codebook decode {dn}x{m} (unfused gather+linear)"),
        "Mrows/s",
        dn as f64 / s.median() / 1e6,
    );
    let mut out_fused = vec![0.0f32; dn * d_dec];
    let s = Samples::collect(reps, || {
        ops::codebook_linear_fwd(
            &books, &dcodes, dn, m, c, d_c, None, &dw, &db, d_dec, true, &mut out_fused, 1,
        );
    });
    push_row(
        &mut t,
        &mut json_rows,
        &format!("codebook decode {dn}x{m} (fused kernel)"),
        "Mrows/s",
        dn as f64 / s.median() / 1e6,
    );
    assert!(
        bits_equal(&out_unfused, &out_fused),
        "fused codebook decode diverged from the unfused pipeline"
    );

    // ---- L3: CSR SpMM, scalar reference vs column-tiled -----------------
    let spmm_d = 32usize;
    let sx: Vec<f32> = (0..n * spmm_d).map(|_| krng.normal() as f32).collect();
    let adj = g.adj();
    let mut spmm_ref = vec![0.0f32; n * spmm_d];
    let s = Samples::collect(reps, || {
        for r in 0..n {
            let orow = &mut spmm_ref[r * spmm_d..(r + 1) * spmm_d];
            orow.fill(0.0);
            for (&j, &v) in adj.row_indices(r).iter().zip(adj.row_values(r)) {
                let xrow = &sx[j as usize * spmm_d..(j as usize + 1) * spmm_d];
                for (o, &xv) in orow.iter_mut().zip(xrow) {
                    *o += v * xv;
                }
            }
        }
    });
    push_row(
        &mut t,
        &mut json_rows,
        &format!("spmm {n}x{spmm_d} (scalar reference)"),
        "Mrows/s",
        n as f64 / s.median() / 1e6,
    );
    let mut spmm_tiled = vec![0.0f32; n * spmm_d];
    let s = Samples::collect(reps, || {
        adj.spmm_row_major(0..n, &sx, spmm_d, &mut spmm_tiled);
    });
    push_row(
        &mut t,
        &mut json_rows,
        &format!("spmm {n}x{spmm_d} (column-tiled)"),
        "Mrows/s",
        n as f64 / s.median() / 1e6,
    );
    assert!(
        bits_equal(&spmm_ref, &spmm_tiled),
        "tiled SpMM diverged from the scalar reference"
    );

    // ---- serving: sharded flush, sequential walk vs parallel fan-out ----
    // Fresh caches per mode and disjoint ids per flush, so every flush
    // pays the full miss path through all four shards; the only variable
    // is the dispatch strategy. Bytes are asserted identical.
    let sn = bench_util::pick(4096usize, 1024);
    let fq = bench_util::pick(256usize, 64);
    let flushes = bench_util::pick(12usize, 6);
    let n_shards = 4usize;
    let build = SageMbBuild {
        name: "ph_fanout".into(),
        coded: true,
        link: false,
        n: sn,
        n_classes: 8,
        d_e: 16,
        hidden: 32,
        batch: 64,
        k1: 5,
        k2: 5,
        c: 16,
        m: 32,
        d_c: 32,
        d_m: 32,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let sg = sbm(SbmCfg::new(sn, 8, 12.0, 2.0), 13)?;
    let scodes = lsh::encode_with(sg.adj(), coding, Threshold::Median, 11, EncodeCfg::default())?;
    let store = ParamStore::init(&manifest, 17);
    let bundle = ServingBundle::new(manifest, &store, Some(scodes), sg.undirected_edges(), sn)?;
    let mut seq_bytes: Vec<Vec<u32>> = Vec::new();
    let mut mode_p50 = [0.0f64; 2];
    for (mi, fanout) in [false, true].into_iter().enumerate() {
        let mut router = ShardRouter::new(
            bundle.split_shards(n_shards)?,
            ServeOpts { threads: 1, cache_capacity: 2 * fq, seed: 11, fanout, ..Default::default() },
        )?;
        let mut lat_us: Vec<f64> = Vec::with_capacity(flushes);
        for f in 0..flushes {
            let fids: Vec<u32> = (0..fq).map(|i| ((f * fq + i) % sn) as u32).collect();
            let (out, dt) = bench_util::timed(|| router.embed_nodes(&fids));
            let bits: Vec<u32> = out?.iter().map(|v| v.to_bits()).collect();
            if fanout {
                assert_eq!(
                    bits, seq_bytes[f],
                    "parallel fan-out served different bytes than the sequential walk"
                );
            } else {
                seq_bytes.push(bits);
            }
            lat_us.push(dt * 1e6);
        }
        lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        mode_p50[mi] = percentile(&lat_us, 50);
        let mode = if fanout { "parallel" } else { "sequential" };
        for p in [50usize, 99] {
            push_row(
                &mut t,
                &mut json_rows,
                &format!("shard flush ({n_shards} shards, {mode})"),
                &format!("p{p} us/flush"),
                percentile(&lat_us, p),
            );
        }
    }

    // ---- serving: cold start, v1 envelope vs v2 section table -----------
    // Open → first served response, the number the zero-copy format is
    // for. The v1 envelope re-parses and copies every section into fresh
    // Vecs; the v2 table verifies the directory and hands out borrowed
    // views, so its load cost is checksumming, not allocation. Bytes
    // served are asserted identical across formats (int8 excepted: its
    // params are dequantized, so only shape/finiteness is checked).
    let cold_dir = std::env::temp_dir().join("hashgnn_bench_coldstart");
    std::fs::create_dir_all(&cold_dir).map_err(|e| hashgnn::Error::Io(e))?;
    let p_v1 = cold_dir.join("cold.v1.bundle");
    let p_v2 = cold_dir.join("cold.v2.bundle");
    let p_i8 = cold_dir.join("cold.v2i8.bundle");
    bundle.save_legacy_v1(&p_v1)?;
    bundle.save(&p_v2)?;
    bundle.save_with(&p_i8, Quant::Int8)?;
    let cold_ids: Vec<u32> = (0..8u32).collect();
    let first_response = |path: &std::path::Path| -> hashgnn::Result<Vec<f32>> {
        let loaded = ServingBundle::load(path)?;
        let mut s = ServeSession::new(
            loaded,
            ServeOpts { threads: 1, cache_capacity: 16, seed: 11, ..Default::default() },
        )?;
        s.embed_nodes(&cold_ids)
    };
    let mut cold_us = [0.0f64; 3];
    let mut first_bytes: Vec<Vec<u32>> = Vec::new();
    for (ci, (label, path)) in
        [("v1 envelope", &p_v1), ("v2 sections", &p_v2), ("v2 int8", &p_i8)]
            .into_iter()
            .enumerate()
    {
        let s = Samples::collect(reps, || {
            let _ = first_response(path).unwrap();
        });
        cold_us[ci] = s.median() * 1e6;
        push_row(
            &mut t,
            &mut json_rows,
            &format!("cold start open->first response ({label})"),
            "us",
            cold_us[ci],
        );
        first_bytes.push(first_response(path)?.iter().map(|v| v.to_bits()).collect());
        let file_bytes = std::fs::metadata(path).map_err(hashgnn::Error::Io)?.len();
        push_row(
            &mut t,
            &mut json_rows,
            &format!("bundle file size ({label})"),
            "bytes",
            file_bytes as f64,
        );
    }
    assert_eq!(
        first_bytes[0], first_bytes[1],
        "v2 section-table load served different bytes than the v1 envelope"
    );
    assert_eq!(first_bytes[0].len(), first_bytes[2].len());
    assert!(
        first_bytes[2].iter().all(|&b| f32::from_bits(b).is_finite()),
        "int8 bundle served non-finite embeddings"
    );
    // Payload bytes copied at load: the v1 parse loop materialises every
    // section (≈ the whole file); the v2 read hands out views, copying
    // only the shard-ownership list (absent here — whole-bundle file).
    let v2 = ServingBundle::load(&p_v2)?;
    assert!(v2.meta.zero_copy && !v2.meta.quantized, "v2 f32 load must be zero-copy");
    let v1_meta = std::fs::metadata(&p_v1).map_err(hashgnn::Error::Io)?;
    push_row(
        &mut t,
        &mut json_rows,
        "payload bytes copied at load (v1 envelope)",
        "bytes",
        v1_meta.len() as f64,
    );
    push_row(&mut t, &mut json_rows, "payload bytes copied at load (v2 sections)", "bytes", 0.0);
    #[cfg(feature = "mmap")]
    {
        let s = Samples::collect(reps, || {
            let loaded = ServingBundle::load_with(&p_v2, true).unwrap();
            let mut sess = ServeSession::new(
                loaded,
                ServeOpts { threads: 1, cache_capacity: 16, seed: 11, mmap: true, ..Default::default() },
            )
            .unwrap();
            let _ = sess.embed_nodes(&cold_ids).unwrap();
        });
        push_row(
            &mut t,
            &mut json_rows,
            "cold start open->first response (v2 mmap)",
            "us",
            s.median() * 1e6,
        );
    }

    // ---- e2e: train step, pipeline on vs off ----------------------------
    // With no artifacts present the Auto backend resolves to the native
    // engine, so this section now always runs offline.
    let engine = Engine::cpu("artifacts")?;
    if let Ok(model) = engine.load("sage_mb_coded") {
        eprintln!("(e2e backend: {})", model.backend_name());
        let nn = model.manifest.hyper_usize("n")?;
        let gg = Arc::new(sbm(SbmCfg::new(nn, 8, 12.0, 2.0), 3)?);
        let labels = Arc::new(gg.labels().unwrap().to_vec());
        let table = Arc::new(lsh::encode_with(
            gg.adj(),
            coding,
            Threshold::Median,
            7,
            EncodeCfg::default(),
        )?);
        let steps = bench_util::pick(20u64, 6);
        for pipeline in [false, true] {
            let task = SageTask {
                graph: gg.clone(),
                labels: labels.clone(),
                features: Features::Codes(table.clone()),
                train_nodes: Arc::new((0..nn as u32).collect()),
            };
            let batcher = sage::SageBatcher::new(task, &model, 9)?;
            let mut store = ParamStore::init(&model.manifest, 1);
            let mut opts = TrainOpts::new(steps);
            opts.pipeline = pipeline;
            let (log, secs) = bench_util::timed(|| train::train(&model, &mut store, batcher, opts));
            let log = log?;
            push_row(
                &mut t,
                &mut json_rows,
                &format!(
                    "sage_mb train step ({}, pipeline={pipeline})",
                    model.backend_name()
                ),
                "steps/s",
                log.losses.len() as f64 / secs,
            );
        }
    } else {
        eprintln!("(model unavailable; e2e section skipped)");
    }

    println!("{}", t.render());

    // ---- machine-readable trajectory file at the repo root ---------------
    let json = Json::obj(vec![
        ("bench", Json::str("perf_hotpath")),
        ("quick", Json::Bool(bench_util::quick())),
        ("n_nodes", Json::num(n as f64)),
        ("encode_n_bits", Json::num(coding.n_bits() as f64)),
        ("available_parallelism", Json::num(avail as f64)),
        ("encode_bit_identical_across_threads", Json::Bool(bit_identical)),
        (
            "encode_speedup_engine_vs_bitbybit",
            Json::num(if bitbybit_rate > 0.0 { engine_best / bitbybit_rate } else { 0.0 }),
        ),
        (
            "shard_flush_p50_speedup_par_vs_seq",
            Json::num(if mode_p50[1] > 0.0 { mode_p50[0] / mode_p50[1] } else { 0.0 }),
        ),
        (
            "cold_start_v2_speedup_vs_v1",
            Json::num(if cold_us[1] > 0.0 { cold_us[0] / cold_us[1] } else { 0.0 }),
        ),
        ("rows", Json::Arr(json_rows)),
    ]);
    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_default()
        .join("BENCH_perf_hotpath.json");
    ser::to_file(&out_path, &json)?;
    eprintln!("wrote {}", out_path.display());
    Ok(())
}
