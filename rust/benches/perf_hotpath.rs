//! §Perf — hot-path microbenchmarks (EXPERIMENTS.md §Perf before/after
//! numbers come from here).
//!
//! L3 paths: Algorithm-1 encode (bit-by-bit vs blocked), median
//! (quickselect vs full sort), code gathering, neighbor sampling, and the
//! end-to-end train step with the batch pipeline on vs off.

mod bench_util;

use std::sync::Arc;

use bench_util::Samples;
use hashgnn::cfg::CodingCfg;
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::graph::NeighborSampler;
use hashgnn::lsh::{self, median_in_place, Threshold};
use hashgnn::params::ParamStore;
use hashgnn::report::Table;
use hashgnn::rng::{Rng, Xoshiro256pp};
use hashgnn::runtime::Engine;
use hashgnn::tasks::sage::{self, Features, SageTask};
use hashgnn::train::{self, TrainOpts};

fn main() -> anyhow::Result<()> {
    bench_util::banner("perf_hotpath", "§Perf microbenches (EXPERIMENTS.md)");
    let mut t = Table::new("hot-path microbenchmarks", &["path", "metric", "value"]);
    let n = bench_util::pick(20000, 5000);
    let reps = bench_util::pick(5, 2);

    // ---- L3: LSH encode -------------------------------------------------
    let g = sbm(SbmCfg::new(n, 8, 12.0, 2.0), 3)?;
    let coding = CodingCfg::new(16, 32)?; // 128 bits
    let s = Samples::collect(reps, || {
        let _ = lsh::encode(g.adj(), coding, Threshold::Median, 7).unwrap();
    });
    t.row(vec![
        "lsh::encode (bit-by-bit)".into(),
        "nodes/s".into(),
        format!("{:.0}", n as f64 / s.median()),
    ]);
    for block in [8usize, 32] {
        let s = Samples::collect(reps, || {
            let _ = lsh::encode_blocked(g.adj(), coding, Threshold::Median, 7, block).unwrap();
        });
        t.row(vec![
            format!("lsh::encode_blocked (B={block})"),
            "nodes/s".into(),
            format!("{:.0}", n as f64 / s.median()),
        ]);
    }

    // ---- L3: median selection -------------------------------------------
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let base: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let s_qs = Samples::collect(20, || {
        let mut buf = base.clone();
        let _ = median_in_place(&mut buf);
    });
    let s_sort = Samples::collect(20, || {
        let mut buf = base.clone();
        buf.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let _ = buf[(buf.len() - 1) / 2];
    });
    t.row(vec![
        "median: quickselect".into(),
        "Melem/s".into(),
        format!("{:.1}", n as f64 / s_qs.median() / 1e6),
    ]);
    t.row(vec![
        "median: full sort (baseline)".into(),
        "Melem/s".into(),
        format!("{:.1}", n as f64 / s_sort.median() / 1e6),
    ]);

    // ---- L3: code gather + neighbor sampling ----------------------------
    let codes = lsh::encode(g.adj(), coding, Threshold::Median, 7)?;
    let ids: Vec<u32> = (0..4096u32).map(|i| i % n as u32).collect();
    let mut buf = Vec::new();
    let s = Samples::collect(50, || {
        codes.gather_int_codes(&ids, &mut buf);
    });
    t.row(vec![
        "codes::gather_int_codes".into(),
        "Mcodes/s".into(),
        format!("{:.1}", ids.len() as f64 / s.median() / 1e6),
    ]);
    let sampler = NeighborSampler::new(&g, 10, 10);
    let batch: Vec<u32> = (0..256u32).collect();
    let mut srng = Xoshiro256pp::seed_from_u64(9);
    let s = Samples::collect(50, || {
        let _ = sampler.sample(&batch, &mut srng);
    });
    t.row(vec![
        "sampler (B=256, 10x10 fanout)".into(),
        "batches/s".into(),
        format!("{:.0}", 1.0 / s.median()),
    ]);

    // ---- e2e: train step, pipeline on vs off ----------------------------
    let engine = Engine::cpu("artifacts")?;
    if let Ok(model) = engine.load("sage_mb_coded") {
        let nn = model.manifest.hyper_usize("n")?;
        let gg = Arc::new(sbm(SbmCfg::new(nn, 8, 12.0, 2.0), 3)?);
        let labels = Arc::new(gg.labels().unwrap().to_vec());
        let table = Arc::new(lsh::encode(gg.adj(), coding, Threshold::Median, 7)?);
        let steps = bench_util::pick(20u64, 6);
        for pipeline in [false, true] {
            let task = SageTask {
                graph: gg.clone(),
                labels: labels.clone(),
                features: Features::Codes(table.clone()),
                train_nodes: Arc::new((0..nn as u32).collect()),
            };
            let batcher = sage::SageBatcher::new(task, &model, 9)?;
            let mut store = ParamStore::init(&model.manifest, 1);
            let mut opts = TrainOpts::new(steps);
            opts.pipeline = pipeline;
            let (log, secs) = bench_util::timed(|| train::train(&model, &mut store, batcher, opts));
            let log = log?;
            t.row(vec![
                format!("sage_mb train step (pipeline={pipeline})"),
                "steps/s".into(),
                format!("{:.2}", log.losses.len() as f64 / secs),
            ]);
        }
    } else {
        eprintln!("(artifacts not built; e2e section skipped)");
    }

    println!("{}", t.render());
    Ok(())
}
